"""Analytic engine backend for cluster-scale simulation.

``SimEngine`` implements the same handle contract as the real JAX
``InferenceEngine`` (submit / step-driven progress / metrics /
match_prefix_len / adapter hooks) but advances on the discrete-event
loop with a roofline cost model (repro.core.optimizer.profiles) instead
of executing matmuls.  Crucially it reuses the *real* page allocator,
content-hash prefix cache AND the *real* unified Scheduler
(repro.engine.scheduler) — the exact admission / budget / role /
finish code the JAX engine runs — and speaks to the *real* distributed
KV pool.  Cache hit/miss/eviction behaviour and scheduling decisions
in benchmarks are produced by the production code; only the FLOPs are
analytic (the roofline cost model plays the ModelRunner's part).

Iteration model (vLLM-style continuous batching):
  * ``mixed_batching=False`` (legacy two-phase, the default): each
    engine iteration is either a prefill chunk (compute-bound) or one
    decode step for the running batch (bandwidth-bound)
  * ``mixed_batching=True``: the shared Scheduler emits the SAME fused
    ``B + K*chunk`` step the real engine runs (budget-trimmed chunks
    from up to ``max_prefills`` concurrent prefills riding one pass
    with the decode batch), priced by ``PerfModel.mixed_step_time`` —
    one roofline over the flattened token batch
  * prefix-cache hits (local or distributed-pool) skip prefill compute
    for the covered tokens; pool fetches pay a transfer-time cost
  * faults (repro.core.diagnostics) scale iteration time via
    ``slowdown`` — a dead device stops making progress.

P/D disaggregation (paper §3.2.5): ``role="prefill"`` engines publish
KV to the pool and hand requests off after the pool's metadata lag;
``role="decode"`` engines pull prefilled KV from the pool — the role
semantics themselves live in the shared Scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

from repro.core.kvcache.pool import DistributedKVPool, KVPoolError
from repro.core.kvcache.tiers import (HostPagePool, SSDPagePool,
                                      validate_wire_dtype)
from repro.core.optimizer.profiles import DEVICES, PerfModel
from repro.core.runtime.sidecar import H2D_BW, TIER_BW
from repro.core.sim.events import EventLoop
from repro.engine.page_table import PageAllocator, chunk_hashes
from repro.engine.request import Request, RequestState
from repro.engine.speculative import FixedLengthDrafter
from repro.engine.scheduler import (EngineMetrics, Scheduler,
                                    SchedulerConfig)
from repro.models.config import ModelConfig


@dataclass
class SimEngineConfig:
    device_type: str = "a10"
    num_devices: int = 1             # TP degree (perf scales, memory adds)
    page_size: int = 64              # tokens per logical KV block
    max_batch: int = 32
    chunk_size: int = 512
    prefix_caching: bool = True
    chunked_prefill: bool = True
    scheduler_overhead_s: float = 0.002
    # fused mixed-batch scheduling (the real engine's default mode):
    # False keeps the legacy two-phase iteration the historical
    # cluster benchmarks were tuned on
    mixed_batching: bool = False
    max_prefills: int = 2           # concurrent PREFILLING requests
    token_budget: int = 0           # 0 => max_batch + max_prefills*chunk
    # P/D disaggregation (paper §3.2.5: the pool enables a DistServe-
    # style "prefill/decode disaggregation remote pool"):
    #   mixed   — normal colocated engine
    #   prefill — prefills, publishes KV to the pool, hands the request
    #             off (never decodes)
    #   decode  — pulls prefilled KV from the pool, decodes only
    role: str = "mixed"
    # tiered KV cache: host-DRAM tier capacity (0 disables — no
    # eviction cascade, drop-and-recompute preemption), the pool wire
    # format ("fp16" matches the roofline's kv_dtype_bytes; "int8"
    # halves the wire bytes) and the streaming-handoff chunk size in
    # pages (0 => eager whole-payload transfer)
    host_cache_gb: float = 0.0
    wire_dtype: str = "fp16"
    handoff_chunk_pages: int = 4
    swap_preemption: bool = True
    # SSD third tier below host DRAM (0 disables): host evictions
    # cascade into a write-behind SSD pool whose dirty queue drains at
    # ``ssd_bw``; the page walk and swap resume read back at the same
    # modelled bandwidth.  Idle-session prefixes survive host pressure
    # here instead of falling to recompute.
    ssd_cache_gb: float = 0.0
    ssd_bw: float = 3.0e9
    # 0 => size the device page count from HBM minus params (default);
    # a positive override pins it (small-KV preemption benchmarks)
    num_pages: int = 0
    # SLO-aware scheduling — the SAME policy knobs as the real engine,
    # handled by the shared Scheduler (deadline-aware admission order,
    # priority preemption, per-class attainment accounting)
    slo_aware: bool = False
    slo_classes: Optional[dict] = None      # None => scheduler defaults
    slo_preempt_headroom: float = 0.25
    slo_preempt_cooldown_s: float = 1.0
    # crash-recovery checkpoint policy (the recovery log): publish a
    # running decode's full KV blocks to the pool every this-many new
    # sequence tokens (0 disables), at most ckpt_budget_bytes per pass
    ckpt_interval_tokens: int = 0
    ckpt_budget_bytes: int = 0
    # high-density multi-LoRA serving: HBM adapter slots (slot 0 is the
    # base model, as in the real engine's bank) with LRU eviction into
    # a bounded host tier; cold loads are priced from the adapter's
    # byte size over the artifact/host tier bandwidths and stall the
    # next step.  lora_autoload / lora_queue_timeout_s mirror
    # EngineConfig — the shared Scheduler's adapter_ready gate keeps
    # non-resident adapters loud on both data planes.
    max_adapters: int = 8
    lora_rank: int = 8
    lora_autoload: bool = True
    lora_queue_timeout_s: float = 30.0
    host_adapter_slots: int = 32
    # speculative n-gram decoding: max drafts per decode row (0
    # disables) and the synthetic acceptance rate the sim resolves
    # verification at.  The sim cannot KNOW acceptance (it has no
    # model), so it prices the verified step with
    # ``PerfModel.spec_step_time`` and emits ``accept_rate * drafts``
    # accepted tokens — flowing through the SAME ``on_spec_batch``
    # bookkeeping (EWMA backoff included) as the real engine, which is
    # what keeps sim/real accounting in parity
    spec_tokens: int = 0
    spec_accept_rate: float = 0.7

    def scheduler_config(self) -> SchedulerConfig:
        """The shared Scheduler, two-phase or fused-mixed-batch — the
        exact admission semantics the real engine runs either way."""
        kw = {}
        if self.slo_classes is not None:
            kw["slo_classes"] = dict(self.slo_classes)
        return SchedulerConfig(
            page_size=self.page_size, max_batch=self.max_batch,
            max_pages_per_seq=0,            # sim: no per-seq page cap
            chunk_size=self.chunk_size,
            chunked_prefill=self.chunked_prefill,
            prefix_caching=self.prefix_caching,
            mixed_batching=self.mixed_batching,
            max_prefills=self.max_prefills if self.mixed_batching else 1,
            token_budget=self.token_budget,
            lora_queue_timeout_s=self.lora_queue_timeout_s,
            handoff_chunk_pages=self.handoff_chunk_pages,
            swap_preemption=self.swap_preemption,
            honor_stop_token=False,     # sim decode tokens are
            role=self.role,             # synthetic zeros
            slo_aware=self.slo_aware,
            slo_preempt_headroom=self.slo_preempt_headroom,
            slo_preempt_cooldown_s=self.slo_preempt_cooldown_s,
            ckpt_interval_tokens=self.ckpt_interval_tokens,
            ckpt_budget_bytes=self.ckpt_budget_bytes,
            spec_tokens=self.spec_tokens, **kw)


class SimEngine:
    def __init__(self, cfg: ModelConfig, loop: EventLoop,
                 sim_cfg: SimEngineConfig = None,
                 kv_pool: Optional[DistributedKVPool] = None,
                 engine_id: str = "sim-0", node: str = "node-0",
                 ssd_pool=None):
        self.cfg = cfg
        self.loop = loop
        self.sc = sim_cfg or SimEngineConfig()
        self.kv_pool = kv_pool
        self.engine_id = engine_id
        self.node = node
        if kv_pool is not None:
            kv_pool.attach_engine(engine_id, node)
        dev = DEVICES[self.sc.device_type]
        self.perf = PerfModel(cfg, dev)
        # TP over num_devices: memory adds, compute/bw scale (0.9 eff.)
        nd = self.sc.num_devices
        self._speed = nd * (0.9 if nd > 1 else 1.0)
        kv_budget = max(dev.hbm_bytes * 0.9 * nd
                        - self.perf.param_bytes, dev.hbm_bytes * 0.05)
        num_pages = self.sc.num_pages or int(
            kv_budget / (self.perf.kv_bytes_per_token * self.sc.page_size))
        # raw per-page payload bytes + the wire size a pool handoff
        # actually moves (int8 quantization halves the fp16 roofline)
        self._page_bytes = int(self.perf.kv_bytes_per_token
                               * self.sc.page_size)
        self._wire_bytes = (self._page_bytes // 2
                            if validate_wire_dtype(self.sc.wire_dtype)
                            == "int8" else self._page_bytes)
        self.host_pool = None
        if self.sc.host_cache_gb > 0:
            self.host_pool = HostPagePool(
                capacity_bytes=int(self.sc.host_cache_gb * (1 << 30)))
        self.ssd_pool = None
        if self.host_pool is not None and ssd_pool is not None:
            # host-shared SSD tier: the cluster passes one
            # SharedSSDPool per host group; this engine attaches a
            # per-engine accounting view (same interface as a private
            # pool, plus cross-engine hit classification)
            self.ssd_pool = ssd_pool.view(engine_id) \
                if hasattr(ssd_pool, "view") else ssd_pool
        elif self.sc.ssd_cache_gb > 0 and self.host_pool is not None:
            self.ssd_pool = SSDPagePool(
                capacity_bytes=int(self.sc.ssd_cache_gb * (1 << 30)),
                ssd_bw=self.sc.ssd_bw)
        self.sched = Scheduler(
            self.sc.scheduler_config(),
            PageAllocator(max(num_pages, 16), self.sc.page_size),
            kv_pool=kv_pool, engine_id=engine_id,
            install_page=self._install_page,
            publish_page=self._publish_page,
            host_pool=self.host_pool,
            page_payload=(lambda pid: True),    # sim: cost model only
            page_bytes=self._page_bytes,
            adapter_ready=lambda name: name in self._adapters,
            ssd_pool=self.ssd_pool)
        if self.sched.drafter is not None:
            # sim tokens are synthetic zeros the n-gram matcher cannot
            # usefully continue; swap in the content-free drafter so
            # spec_accept_rate shapes acceptance (see FixedLengthDrafter)
            self.sched.drafter = FixedLengthDrafter(
                **vars(self.sched.drafter))
        self.slowdown_fn: Callable[[], float] = lambda: 1.0
        self._busy = False
        # busy-transition hook: the cluster keeps a busy-engine COUNT
        # from these edges so its per-event done() predicate is O(1)
        # instead of scanning every engine's has_work
        self.on_busy_changed: Optional[Callable[[bool], None]] = None
        # adapter tiering mirrored from the real ModelRunner: a bounded
        # HBM bank (name -> LRU tick; slot 0 is the base model, hence
        # max_adapters - 1 slots) cascading into a bounded host tier.
        # The sim stores no weights — a cold load prices the adapter
        # bytes over the artifact (or host) tier bandwidth and stalls
        # the engine's next step by that time.
        self._adapters: dict = {}
        self._lru_tick = 0
        self._host_adapters: dict = {}
        self._deferred_unloads: set = set()
        self._adapter_penalty_s = 0.0
        self._adapter_bytes = self.perf.lora_adapter_bytes(
            self.sc.lora_rank)
        self._lora = dict(cold_loads=0, cold_load_s=0.0, evictions=0,
                          host_hits=0)
        self._m: dict = {}              # sim-only counters (migrations)
        self.alive = True

    # ---------------------------------------------------------- contract
    def submit(self, req: Request) -> None:
        if (req.lora_adapter and self.sc.lora_autoload
                and req.lora_adapter not in self._adapters):
            try:
                self.register_adapter(req.lora_adapter)
            except RuntimeError:
                pass    # all slots pinned: queue behind adapter_ready
        self.sched.enqueue(req, self.loop.clock.now)
        self._kick()

    def _adapters_in_use(self) -> set:
        return {r.lora_adapter
                for r in self.sched.running + self.sched.prefills
                if r.lora_adapter}

    def _touch_adapter(self, name: str) -> None:
        self._lru_tick += 1
        self._adapters[name] = self._lru_tick

    def register_adapter(self, name: str, weights=None) -> None:
        """Same tier semantics as ``ModelRunner.register_adapter``;
        the weights are a cost, not arrays: host-tier hits pay the
        host->device copy, artifact-store loads additionally pay the
        local-tier fetch.  The stall lands on the next step."""
        self._deferred_unloads.discard(name)
        if name in self._adapters:
            self._touch_adapter(name)
            return
        slots = max(self.sc.max_adapters - 1, 1)
        if len(self._adapters) >= slots:
            in_use = self._adapters_in_use()
            victim = next(
                (n for n in sorted(self._adapters,
                                   key=self._adapters.get)
                 if n not in in_use), None)
            if victim is None:
                raise RuntimeError(
                    "adapter slots exhausted and every resident adapter "
                    "is pinned by an in-flight batch")
            self.unregister_adapter(victim)
            self._lora["evictions"] += 1
        cost = self._adapter_bytes / H2D_BW
        if name in self._host_adapters:
            self._host_adapters.pop(name)
            self._lora["host_hits"] += 1
        else:
            cost += self._adapter_bytes / TIER_BW["local"]
        self._touch_adapter(name)
        self._lora["cold_loads"] += 1
        self._lora["cold_load_s"] += cost
        self._adapter_penalty_s += cost
        self._kick()    # a gated request may now be admissible

    def unregister_adapter(self, name: str) -> None:
        if name not in self._adapters:
            return
        if name in self._adapters_in_use():
            # never disturb an in-flight batch: unload once it drains
            self._deferred_unloads.add(name)
            return
        self._adapters.pop(name)
        if self.sc.host_adapter_slots > 0:
            self._host_adapters[name] = True
            while len(self._host_adapters) > self.sc.host_adapter_slots:
                self._host_adapters.pop(next(iter(self._host_adapters)))

    def _flush_deferred_unloads(self) -> None:
        if not self._deferred_unloads:
            return
        in_use = self._adapters_in_use()
        for name in list(self._deferred_unloads):
            if name not in in_use:
                self._deferred_unloads.discard(name)
                self.unregister_adapter(name)

    @property
    def adapters(self) -> List[str]:
        return sorted(self._adapters)

    def match_prefix_len(self, tokens) -> int:
        return self.sched.match_prefix_len(tokens)

    @property
    def queue_depth(self) -> int:
        """Cheap routing-load accessor (== metrics() num_running +
        num_waiting) — see SchedulerCore.queue_depth."""
        return self.sched.queue_depth

    def healthy(self) -> bool:
        return self.alive and self.slowdown_fn() > 0.0

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # back-compat views over the shared scheduler's queues
    @property
    def alloc(self) -> PageAllocator:
        return self.sched.alloc

    @property
    def waiting(self) -> List[Request]:
        return self.sched.waiting

    @property
    def running(self) -> List[Request]:
        return self.sched.running

    @property
    def finished(self) -> List[Request]:
        return self.sched.finished

    @property
    def prefilling(self) -> Optional[Request]:
        return self.sched.prefills[0] if self.sched.prefills else None

    @property
    def handoff(self):
        return self.sched.handoff

    @handoff.setter
    def handoff(self, fn) -> None:
        self.sched.handoff = fn

    # ---------------------------------------------------------- scheduling
    def _kick(self) -> None:
        if not self._busy and self.has_work:
            self._set_busy(True)
            self.loop.after(0.0, self._iterate)

    def _set_busy(self, flag: bool) -> None:
        if self._busy != flag:
            self._busy = flag
            if self.on_busy_changed is not None:
                self.on_busy_changed(flag)

    def _install_page(self, pid: int, payload, req: Request,
                      now: float, source: str = "pool",
                      stream: bool = False, nbytes: int = 0) -> None:
        """Payload hook for the shared Scheduler's page walk: the sim
        stores no arrays — each fetched page attributes a transfer-time
        cost to the request.  Host-tier pages move raw bytes at
        ``dram_bw``; SSD-tier pages read back at the modelled
        ``ssd_bw``; pool pages move wire bytes (int8-compressed when
        configured) at ``network_bw``.  Head-group pages charge
        ``_fetch_head_s`` (they gate the tail recompute); streamed
        groups charge ``_fetch_stream_s``, which ``_iterate`` overlaps
        with the step's compute — the chunked-handoff pipeline."""
        nbytes = nbytes or self._page_bytes
        if source == "host":
            cost = nbytes / self.host_pool.dram_bw
        elif source == "ssd":
            cost = nbytes / self.ssd_pool.ssd_bw
        else:
            cost = nbytes / self.kv_pool.network_bw
        attr = "_fetch_stream_s" if stream else "_fetch_head_s"
        setattr(req, attr, getattr(req, attr, 0.0) + cost)

    def _publish_page(self, pid: int, block_hash: str, req: Request,
                      now: float) -> None:
        """Payload hook for the shared prompt-page registration: the
        sim publishes a payload-less record sized by the cost model
        (wire bytes — the int8 format halves them)."""
        self.kv_pool.publish(block_hash, True, self.engine_id, now,
                             size_bytes=self._wire_bytes)

    # ------------------------------------------------ predictive promotion
    def promote_session(self, session_id: str) -> int:
        """Prefetch the session's SSD-resident pages back into host
        DRAM ahead of the predicted turn.  The sim prices the SSD read
        like the real engine pays it: the pages land after a scheduled
        delay of bytes/ssd_bw, OFF the critical path (no engine stall —
        that is the whole point; only the promoter's landing time is
        modelled).  Returns the number of pages scheduled."""
        if self.ssd_pool is None:
            return 0
        keys = self.sched.session_promotable(session_id)
        if not keys:
            return 0
        delay = len(keys) * self._page_bytes / self.ssd_pool.ssd_bw

        def land() -> None:
            now = self.loop.clock.now
            for key in keys:
                payload = self.ssd_pool.get(key, now)
                if payload is not None:
                    self.sched.complete_promotion(
                        key, payload, self._page_bytes, now, session_id)

        self.loop.after(delay, land)
        return len(keys)

    def _iterate(self) -> None:
        now = self.loop.clock.now
        slow = self.slowdown_fn()
        if not self.alive or slow <= 0.0:
            self._set_busy(False)     # dead engine: progress stops
            return
        self._flush_deferred_unloads()
        out = self.sched.schedule(now)
        if not (out.prefills or out.decode):
            if any(r.lora_adapter and r.lora_adapter not in self._adapters
                   for r in self.sched.waiting):
                # requests gated on a non-resident adapter: poll so the
                # control plane's next sync (or the shed timeout) is
                # observed even though no submit will re-kick us
                self.loop.after(0.1, self._iterate)
                return
            self._set_busy(False)
            return
        batch = out.decode
        chunk_total = sum(w.chunk_len for w in out.prefills)
        # transfer charges from the page walk / swap-in: head bytes
        # gate the step (the engine cannot attend over pages that have
        # not landed), streamed chunk groups overlap with the step's
        # compute — effective cost max(compute, stream), the chunked-
        # handoff pipeline (eager mode puts everything in head)
        head = stream = 0.0
        for r in [w.req for w in out.prefills] + list(batch):
            head += getattr(r, "_fetch_head_s", 0.0)
            stream += getattr(r, "_fetch_stream_s", 0.0)
            r._fetch_head_s = 0.0           # type: ignore[attr-defined]
            r._fetch_stream_s = 0.0         # type: ignore[attr-defined]
        if out.spec:
            # speculative verification: draft tokens add FLOPs but no
            # extra weight/KV byte traffic — the roofline term the
            # expected decode speedup (and admission parity with the
            # real engine) rests on
            ctx = sum(r.total_tokens for r in batch) / len(batch)
            comp = self.perf.spec_step_time(
                len(batch), ctx, sum(len(d) for d in out.spec),
                chunk_total) / (self._speed * slow)
        elif batch and out.prefills:
            # fused mixed batch: decode rows + budget-trimmed prefill
            # chunks in ONE pass, one roofline over the token batch
            ctx = sum(r.total_tokens for r in batch) / len(batch)
            comp = self.perf.mixed_step_time(len(batch), ctx,
                                             chunk_total) \
                / (self._speed * slow)
        elif out.prefills:
            comp = self.perf.prefill_time(chunk_total) \
                / (self._speed * slow)
        else:
            ctx = sum(r.total_tokens for r in batch) / len(batch)
            comp = self.perf.decode_step_time(len(batch), ctx) \
                / (self._speed * slow)
        # adapter cold loads stall the step like head-group KV fetches:
        # the batch cannot run until the weights land on device
        head += self._adapter_penalty_s
        self._adapter_penalty_s = 0.0
        dt = self.sc.scheduler_overhead_s + head + max(comp, stream)
        done_t = now + dt
        for w in out.prefills:
            if w.chunk_len == 0:
                continue                    # budget-starved this step
            if self.sched.note_prefill_progress(w.req, w.chunk_len):
                self._finish_prefill(w.req, done_t)
        if batch:
            if out.spec:
                # synthetic acceptance: the accept-rate share of each
                # row's drafts lands, plus the bonus token — routed
                # through the same on_spec_batch bookkeeping (EWMA
                # backoff, acceptance counters) as the real engine
                rate = self.sc.spec_accept_rate
                # accepted tokens ARE the draft prefix by definition;
                # only the bonus/correction sample is synthetic.  The
                # round() keeps short (1-token) drafts acceptable so
                # the EWMA backoff sees the configured rate, not a
                # floor()-induced zero
                emitted = [list(d[:min(round(rate * len(d)), len(d))])
                           + [0] for d in out.spec]
                self.sched.on_spec_batch(batch, out.spec, emitted,
                                         done_t)
            else:
                self.sched.on_decode_batch(batch, [0] * len(batch),
                                           done_t)
        self.loop.after(dt, self._iterate)

    def _finish_prefill(self, req: Request, t: float) -> None:
        self.sched.register_prompt_pages(req, t)
        if self.sched.wants_handoff:
            # disaggregated: KV is in the pool; hand the request to a
            # decode engine and free this engine for the next prefill.
            # Deliver after the pool's metadata lag so the decode side
            # sees the published blocks (the scheduler tracks the
            # in-flight request so drain predicates don't observe a
            # momentarily idle pair).
            self.sched.handoff_prefill(req, t)
            lag = self.kv_pool.metadata_lag if self.kv_pool else 0.0
            # schedule from the (forward-dated) prefill completion time
            self.loop.schedule(t + lag * 1.01,
                               lambda: self.sched.deliver_handoff(req))
            return
        self.sched.finish_prefill(req, 0, t)
        self.sched.note_tokens(t, 1)

    # ------------------------------------------------------- migration
    def migrate_out(self, req: Request, target: "SimEngine") -> bool:
        """Live-migrate a RUNNING request to ``target`` via the pool
        (paper §3.1: the distributed KV cache runtime supports "request
        migration").  All of the sequence's KV blocks — prompt AND
        generated — are published; the target re-admits the request and
        pulls them by hash, so only the block tail is recomputed."""
        if req not in self.sched.running or self.kv_pool is None:
            return False
        now = self.loop.clock.now
        # publish every full block of (prompt + generated) tokens
        seq = list(req.prompt_tokens) + [0] * len(req.output_tokens)
        hashes = chunk_hashes(seq, self.sc.page_size,
                              req.lora_adapter or "")
        try:
            for h in hashes:
                self.kv_pool.publish(h, True, self.engine_id, now,
                                     size_bytes=self._wire_bytes)
        except KVPoolError:
            return False    # pool partitioned: migration refused
        self.sched.drop_running(req, now)
        # target treats the full sequence-so-far as its "prompt": the
        # generated tokens keep their identity via req.output_tokens
        req._migrated_prompt = seq            # type: ignore[attr-defined]
        req.prompt_tokens = seq
        req.prefill_done_tokens = 0
        req.state = RequestState.QUEUED
        self._m["migrations"] = self._m.get("migrations", 0) + 1
        # deliver after metadata visibility so the KV actually transfers
        self.loop.schedule(now + self.kv_pool.metadata_lag * 1.01,
                           lambda: target.submit(req))
        return True

    # ---------------------------------------------------------- metrics
    def metrics(self) -> EngineMetrics:
        m = self.sched.metrics(
            self.loop.clock.now,
            loaded_adapters=tuple(sorted(self._adapters)))
        m.lora_cold_loads = self._lora["cold_loads"]
        m.lora_cold_load_s = self._lora["cold_load_s"]
        m.lora_evictions = self._lora["evictions"]
        m.lora_host_hits = self._lora["host_hits"]
        return m
