"""Chaos harness: declarative failure schedules for the cluster sim.

A :class:`ChaosSchedule` is a list of timed :class:`ChaosEvent`\\ s the
:class:`~repro.core.sim.cluster_sim.ServingCluster` arms on its event
loop at ``run()`` time.  Four scenario kinds cover the failure modes
the paper's diagnostic/mock-up tooling (§3.2.8) is built to exercise:

``engine_crash``
    The pod dies mid-decode: a ``DEVICE_LOST`` fault is injected (the
    heartbeat disappears from telemetry) and the engine stops
    iterating.  Detection flows through the normal scrape -> monitor ->
    remediate path; with crash recovery enabled the dead engine's
    requests are harvested (``Scheduler.crash_takeover``) and resume on
    survivors from their last recovery-log checkpoint.

``straggler``
    A slow node, not a dead one: ``SILENT_DEGRADATION`` or
    ``THERMAL_THROTTLE`` through the engine's ``slowdown_fn`` hook for
    ``duration`` seconds.  The gateway's straggler hedging and the
    monitor's quarantine state machine are the defenses.

``kv_partition``
    The distributed KV pool becomes unreachable for ``duration``
    seconds: fetch/publish raise ``KVPoolError`` and the schedulers
    must degrade to recompute behind their retry/backoff breaker.

``gateway_restart``
    The gateway process bounces mid-stream: for ``duration`` seconds
    new dispatches are deferred (client retries), and the gateway
    comes back with its routing-policy state, rate-limit buckets and
    cordon set wiped — warm state is not durable across restarts.

Events with no ``target`` pick the busiest live engine at fire time,
so a schedule written before the run still hits an engine that
actually holds work.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.diagnostics.tools import FaultKind

CHAOS_KINDS = ("engine_crash", "straggler", "kv_partition",
               "gateway_restart")


@dataclass(frozen=True)
class ChaosEvent:
    at: float                           # fire time (sim-clock seconds)
    kind: str                           # one of CHAOS_KINDS
    target: Optional[str] = None        # engine id; None => busiest
    duration: float = 0.0               # straggler/partition/restart window
    severity: float = 1.0               # straggler fault severity
    fault: FaultKind = FaultKind.SILENT_DEGRADATION   # straggler flavor

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"expected one of {CHAOS_KINDS}")
        if self.at < 0:
            raise ValueError(f"chaos event at={self.at} before t=0")


@dataclass
class ChaosSchedule:
    events: List[ChaosEvent] = field(default_factory=list)

    def __iter__(self):
        return iter(sorted(self.events, key=lambda e: e.at))

    def __len__(self):
        return len(self.events)

    def __add__(self, other: "ChaosSchedule") -> "ChaosSchedule":
        """Compose schedules: ``crash(10) + straggler(20, 15)``."""
        return ChaosSchedule(list(self.events) + list(other.events))

    # convenience constructors for the common single-scenario runs
    @classmethod
    def engine_crash(cls, at: float,
                     target: Optional[str] = None) -> "ChaosSchedule":
        return cls([ChaosEvent(at, "engine_crash", target=target)])

    @classmethod
    def straggler(cls, at: float, duration: float, severity: float = 1.0,
                  target: Optional[str] = None,
                  fault: FaultKind = FaultKind.SILENT_DEGRADATION
                  ) -> "ChaosSchedule":
        return cls([ChaosEvent(at, "straggler", target=target,
                               duration=duration, severity=severity,
                               fault=fault)])

    @classmethod
    def kv_partition(cls, at: float, duration: float) -> "ChaosSchedule":
        return cls([ChaosEvent(at, "kv_partition", duration=duration)])

    @classmethod
    def gateway_restart(cls, at: float,
                        duration: float = 1.0) -> "ChaosSchedule":
        return cls([ChaosEvent(at, "gateway_restart", duration=duration)])
