"""ServingCluster: the full AIBrix stack wired over the event loop.

Gateway (+routing policy) -> SimEngine fleet -> distributed KV pool,
with the metric pump (AI runtime scrape), autoscaler reconciliation
through the ClusterManager (cold starts included), failure injection,
and the GPU optimizer's desired-count feed.  This is the testbed every
cluster-level benchmark runs on.

Role pools: ``ClusterConfig.roles`` accepts 'mixed' (default),
'<n>P<m>D' (static disaggregation) or 'auto' (even initial split).
Disaggregated fleets are driven through the SAME
:class:`~repro.core.orchestration.pools.RolePoolManager` the real
launcher uses — the gateway routes new requests to the prefill pool,
handoffs load-balance over the decode pool, and with
``ClusterConfig.rebalance`` set an :class:`AttainmentRebalancer`
migrates members between pools live under the discrete-event clock
(``benchmarks/bench_pd_pools.py`` measures it).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional

from repro.core.autoscaler.metrics import MetricStore
from repro.core.autoscaler.policies import Autoscaler
from repro.core.diagnostics.tools import (DiagnosticMonitor, FailureInjector,
                                          FaultKind, Telemetry)
from repro.core.gateway.gateway import Gateway, RateLimit
from repro.core.kvcache.pool import DistributedKVPool
from repro.core.lora.manager import AdapterSpec, LoRAController
from repro.core.sim.chaos import ChaosSchedule
from repro.core.orchestration.cluster import ClusterManager, PodState
from repro.core.orchestration.pools import (AttainmentRebalancer,
                                            RebalanceConfig,
                                            RolePoolManager,
                                            parse_role_spec)
from repro.core.runtime.sidecar import (AIRuntime, ColdStartManager,
                                        ModelArtifact)
from repro.core.sim.events import EventLoop, SimClock
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig
from repro.core.sim.workloads import (StreamingSummary, TimedRequest,
                                      summarize)
from repro.models.config import ModelConfig


@dataclass
class ClusterConfig:
    routing_policy: str = "least-request"
    routing_kw: dict = field(default_factory=dict)
    # -- sharded gateway core --
    # split the gateway's hot state (session pins, rate-limit buckets,
    # per-shard stats + routable cache) into N independent shards keyed
    # by hash(session_id | user); 1 = the monolithic gateway
    gateway_shards: int = 1
    device_type: str = "a10"
    num_engines: int = 4
    engine: SimEngineConfig = None
    use_kv_pool: bool = False
    kv_pool_gb: float = 64.0
    kv_pool_policy: str = "s3fifo"
    kv_pool_bw: float = 12.5e9       # handoff fabric (bytes/s)
    autoscaler: Optional[Autoscaler] = None
    rate_limit: Optional[RateLimit] = None   # None => gateway defaults
    metric_delay_s: float = 0.0      # legacy metrics-path propagation
    scrape_period_s: float = 1.0
    autoscale_period_s: float = 2.0
    model_bytes: float = 14e9        # ~7B bf16 artifact
    telemetry: bool = False
    # -- role pools (P/D disaggregation at cluster scale) --
    # 'mixed' | '<n>P<m>D' | 'auto' (even split, adapted live when a
    # rebalance config is set).  Disaggregation implies the KV pool.
    roles: str = "mixed"
    rebalance: Optional[RebalanceConfig] = None
    pool_poll_period_s: float = 0.5  # drain-completion polling cadence
    # -- chaos harness + failure handling --
    # a ChaosSchedule arms scripted failures on the event loop
    # (telemetry/diagnostics are force-enabled so detection can run)
    chaos: Optional[ChaosSchedule] = None
    # harvest a dead engine's queued/in-flight requests and re-deliver
    # them to survivors (KV-backed resume when the recovery log covers
    # them); False = the pre-chaos behavior, requests on a dead engine
    # are simply lost
    crash_recovery: bool = True
    # straggler hedging: re-route queued work off engines whose
    # windowed tokens/s < hedge_ratio x fleet median (0 disables)
    hedge_ratio: float = 0.0
    hedge_period_s: float = 1.0
    # client behavior across a gateway restart: deferred dispatches
    # retry this long after the gateway comes back
    gw_retry_delay_s: float = 0.25
    # -- high-density multi-LoRA serving (paper §3.2.1) --
    # register lora-0..lora-{n-1} with a LoRAController wired into the
    # gateway (adapter registry + demand feed + lora-affinity
    # endpoints) and replanned periodically against observed demand.
    # 0 disables the adapter control plane.
    lora_adapters: int = 0
    # controller slot budget per pod; 0 => the engine config's
    # max_adapters - 1 (slot 0 is the base model)
    lora_slots_per_pod: int = 0
    lora_replan_period_s: float = 2.0
    lora_min_replicas: int = 1
    lora_max_replicas: int = 4
    # -- million-session scale --
    # False streams every finished Request into a StreamingSummary
    # (engines' finish_sink) and drops the object, so memory stays flat
    # no matter how many requests a run pushes through; summary() then
    # reads the streaming twin instead of summarize(all_requests)
    retain_requests: bool = True
    # per-priority-class TTFT targets fed to the StreamingSummary so
    # summary() can report ttft_attainment without retaining requests
    ttft_slo_s: Optional[Dict[str, float]] = None
    # -- host-shared SSD pool --
    # True lifts the SSD tier from per-engine to per-host: every
    # ``engines_per_host`` consecutive engines attach to ONE content-
    # addressed SharedSSDPool (capacity = per-engine ssd_cache_gb x
    # group size, one write-behind drain), so a prefix evicted by
    # engine A is an SSD hit for engine B instead of a duplicate copy
    ssd_shared: bool = False
    engines_per_host: int = 2
    # -- predictive KV promotion --
    # promote_lead_s > 0 (with the session routing policy) arms the
    # per-session think-time EWMA predictor: the cluster polls due
    # promotions every promote_poll_period_s and asks the pinned
    # engine to prefetch that session's SSD pages into host DRAM
    # before the predicted turn lands (off the critical path)
    promote_lead_s: float = 0.0
    promote_poll_period_s: float = 0.5


class ServingCluster:
    def __init__(self, cfg: ModelConfig, ccfg: ClusterConfig):
        self.cfg = cfg
        self.ccfg = ccfg
        self.loop = EventLoop()
        self.clock = self.loop.clock
        self.roles = self._resolve_roles(ccfg)
        self.disaggregated = any(r != "mixed" for r in self.roles)
        if self.disaggregated:
            ccfg.num_engines = len(self.roles)
            if ccfg.autoscaler is not None:
                # replica autoscaling actuates through the gateway only
                # and would bypass the role pools (retired members would
                # keep taking handoffs); elastic role pools are a
                # ROADMAP follow-up — refuse the combination for now
                raise ValueError("autoscaler + disaggregated roles is "
                                 "not supported yet: size the pools "
                                 "with ClusterConfig.rebalance instead")
        self.kv_pool = None
        if ccfg.use_kv_pool or self.disaggregated:
            per_tok = 1  # placeholder, real size set by engines' PerfModel
            self.kv_pool = DistributedKVPool(
                capacity_bytes=int(ccfg.kv_pool_gb * (1 << 30)),
                policy=ccfg.kv_pool_policy, clock=self.clock,
                network_bw=ccfg.kv_pool_bw)
        routing_kw = dict(ccfg.routing_kw)
        if ccfg.promote_lead_s > 0 and ccfg.routing_policy == "session":
            routing_kw.setdefault("promote_lead_s", ccfg.promote_lead_s)
        self.gateway = Gateway(policy=ccfg.routing_policy,
                               default_limit=ccfg.rate_limit,
                               clock=self.clock,
                               shards=ccfg.gateway_shards, **routing_kw)
        self.pool_mgr = RolePoolManager(clock=self.clock,
                                        gateway=self.gateway)
        self.rebalancer = (AttainmentRebalancer(ccfg.rebalance)
                           if ccfg.rebalance is not None
                           and self.disaggregated else None)
        self.engines: Dict[str, SimEngine] = {}
        self.runtimes: Dict[str, AIRuntime] = {}
        self.metrics = MetricStore(propagation_delay_s=ccfg.metric_delay_s)
        self.injector = FailureInjector()
        self.monitor = DiagnosticMonitor()
        self.diagnoses: List = []
        self.all_requests: List = []
        self.stream_summary = (None if ccfg.retain_requests else
                               StreamingSummary(ttft_slo_s=ccfg.ttft_slo_s))
        # engines with a pending iteration event, maintained via the
        # on_busy_changed edge callback — run()'s done() predicate
        # checks this counter instead of scanning every engine's
        # has_work after each event (the full scan only runs when the
        # count hits zero, where it still catches dead engines whose
        # queues are non-empty but whose iteration has stopped)
        self._busy_engines = 0
        self.rejected: int = 0
        self.scale_history: List[tuple] = []
        # chaos / failure-handling accounting
        if ccfg.chaos is not None:
            ccfg.telemetry = True    # detection must run to remediate
        self.chaos_log: List[tuple] = []
        self.crashed_requests: List[int] = []   # ids on an engine at crash
        self.crash_recovered: List[int] = []    # ids harvested + redelivered
        self.quarantines = 0
        self.readmits = 0
        self.hedged = 0
        self.gw_restarts = 0
        self.gw_deferred = 0
        self._gateway_down_until = float("-inf")
        # orchestration (pods + cold start) — used when autoscaling
        self.cold = ColdStartManager(streaming_loader=True)
        self.cold.register_artifact(
            ModelArtifact(cfg.name, ccfg.model_bytes,
                          tier_by_node={"node-0": "dram"}))
        self.cluster = ClusterManager(self.cold, clock=self.clock)
        # host-shared SSD pools: host group id -> SharedSSDPool (built
        # lazily as engines spawn; replacements land in their group)
        self._host_ssd: Dict[str, object] = {}
        self.promotions = 0        # promoter prefetch calls issued
        for i in range(max(ccfg.num_engines,
                           (ccfg.autoscaler.max_replicas
                            if ccfg.autoscaler else ccfg.num_engines))):
            self.cluster.add_node(f"node-{i}", ccfg.device_type, 8)
            if i > 0:
                self.cold.note_cached(cfg.name, f"node-{i}", "local")
        for i in range(ccfg.num_engines):
            self._spawn_engine(ready=True, role=self.roles[i])
        # adapter control plane: registry + density placement wired
        # into the gateway (demand feed + lora-affinity endpoints);
        # later-spawned engines join as pods in _spawn_engine
        self.lora_ctrl: Optional[LoRAController] = None
        self._lora_slots = 0
        if ccfg.lora_adapters > 0:
            ecfg = ccfg.engine or SimEngineConfig()
            self._lora_slots = ccfg.lora_slots_per_pod \
                or max(ecfg.max_adapters - 1, 1)
            self.lora_ctrl = LoRAController(
                min_replicas=ccfg.lora_min_replicas,
                max_replicas=ccfg.lora_max_replicas)
            for i in range(ccfg.lora_adapters):
                # zipf-shaped prior; refresh_demand replaces it with
                # gateway-observed rates once traffic flows
                self.lora_ctrl.register(AdapterSpec(
                    f"lora-{i}", cfg.name, requests_per_s=1.0 / (i + 1)))
            for eid in self.engines:
                self.lora_ctrl.add_pod(eid, capacity=self._lora_slots)
            self.gateway.attach_lora_controller(self.lora_ctrl)
            self.lora_ctrl.sync(self.engines)

    @staticmethod
    def _resolve_roles(ccfg: ClusterConfig) -> List[str]:
        if ccfg.roles == "auto":
            if ccfg.num_engines < 2:
                raise ValueError("roles='auto' needs num_engines >= 2 "
                                 "(one prefill AND one decode member)")
            # the live rebalancer corrects the split; absent a demand
            # forecast the even split is the neutral starting point
            # (launch/serve.py seeds from the optimizer's split_roles)
            n_p = max(ccfg.num_engines // 2, 1)
            return (["prefill"] * n_p
                    + ["decode"] * (ccfg.num_engines - n_p))
        return parse_role_spec(ccfg.roles, ccfg.num_engines)

    # ------------------------------------------------------------ engines
    def _spawn_engine(self, ready: bool = False,
                      role: str = "mixed") -> str:
        eid = f"engine-{len(self.runtimes)}"
        node = f"node-{len(self.runtimes) % max(len(self.cluster.nodes), 1)}"
        ecfg = self.ccfg.engine or SimEngineConfig(
            device_type=self.ccfg.device_type)
        if ecfg.role != role:
            ecfg = dataclasses.replace(ecfg, role=role)
        eng = SimEngine(self.cfg, self.loop, ecfg, kv_pool=self.kv_pool,
                        engine_id=eid, node=node,
                        ssd_pool=self._host_ssd_pool(ecfg))
        eng.slowdown_fn = (lambda e=eid: self.injector.slowdown_factor(e))
        eng.on_busy_changed = self._note_busy
        if self.stream_summary is not None:
            eng.sched.finish_sink = self.stream_summary.observe
        self.engines[eid] = eng
        self.runtimes[eid] = AIRuntime(eng, pod_id=eid, node=node)
        ctrl = getattr(self, "lora_ctrl", None)
        if ctrl is not None:
            ctrl.add_pod(eid, capacity=self._lora_slots)
        if ready:
            self.pool_mgr.add_engine(eid, eng, role)
        else:
            # simulate cold start before joining the gateway/pools
            pod = self.cluster.create_pod(self.cfg.name,
                                          self.ccfg.device_type)
            delay = (pod.ready_at - self.clock.now) if pod else 30.0
            self.loop.after(delay,
                            lambda: self.pool_mgr.add_engine(eid, eng,
                                                             role))
        return eid

    def _host_ssd_pool(self, ecfg: SimEngineConfig):
        """The spawning engine's host-group SharedSSDPool (created on
        first use), or None when sharing is off / the engine has no SSD
        tier configured.  Groups are ``engines_per_host`` consecutive
        spawn slots — the sim's stand-in for physical co-location."""
        if (not self.ccfg.ssd_shared or ecfg.ssd_cache_gb <= 0
                or ecfg.host_cache_gb <= 0):
            return None
        from repro.core.kvcache.tiers import SharedSSDPool
        per_host = max(self.ccfg.engines_per_host, 1)
        host = f"host-{len(self.runtimes) // per_host}"
        pool = self._host_ssd.get(host)
        if pool is None:
            pool = self._host_ssd[host] = SharedSSDPool(
                capacity_bytes=int(ecfg.ssd_cache_gb * (1 << 30)
                                   * per_host),
                ssd_bw=ecfg.ssd_bw)
        return pool

    def ssd_pools(self) -> List:
        """The underlying SSD pool objects: one per host group when
        shared, one per engine otherwise (summary + bench accounting)."""
        if self._host_ssd:
            return list(self._host_ssd.values())
        return [e.ssd_pool for e in self.engines.values()
                if getattr(e, "ssd_pool", None) is not None]

    def _note_busy(self, flag: bool) -> None:
        self._busy_engines += 1 if flag else -1

    def _retire_engine(self) -> None:
        live = [e for e in self.engines if e in self.gateway.engines]
        if len(live) <= 1:
            return
        # retire the emptiest engine (graceful: it finishes its work).
        # Through the pool manager, NOT the gateway alone — a stale
        # role-pool member would keep receiving handoffs and keep
        # counting toward pool attainment after retirement
        eid = min(live, key=lambda e: self.engines[e].metrics().num_running)
        self.pool_mgr.remove_engine(eid)
        if self.lora_ctrl is not None:
            self.lora_ctrl.remove_pod(eid)

    @property
    def active_replicas(self) -> int:
        return len(self.gateway.engines)

    # ------------------------------------------------------------ pumps
    def _scrape(self) -> None:
        now = self.clock.now
        # snapshot: remediation may spawn replacement engines mid-scrape
        for eid, rt in list(self.runtimes.items()):
            if eid not in self.gateway.engines:
                continue
            for k, v in rt.scrape().items():
                self.metrics.record(now, k, v)
            if self.ccfg.telemetry:
                m = rt.engine.metrics()
                sample = Telemetry(pod_id=eid, t=now,
                                   tokens_per_sec=m.tokens_per_sec)
                sample = self.injector.perturb(sample)
                for d in self.monitor.observe(sample):
                    self.diagnoses.append(d)
                    self._remediate(d)

    def _remediate(self, d) -> None:
        eid = d.pod_id
        if d.action == "quarantine":
            # soft fault confirmed: cordon out of routing while the
            # monitor's re-admit probe runs; the engine stays alive
            # and keeps draining its in-flight work
            if eid in self.gateway.engines:
                self.gateway.cordon(eid)
                self.quarantines += 1
            return
        if d.action == "readmit":
            self.gateway.uncordon(eid)
            self.readmits += 1
            return
        if d.action in ("restart", "cordon", "drain"):
            if eid not in self.gateway.engines:
                return
            # remove from the role pools too (handoffs and pool
            # attainment must stop seeing the degraded member) and
            # spin up the replacement with a cold start UNDER THE
            # SAME ROLE, so remediation preserves the P/D topology
            role = self.pool_mgr.role_of(eid)
            src_pool = role if role in self.pool_mgr.POOLS else "mixed"
            eng = self.engines.get(eid)
            lost: List = []
            if eng is not None and not eng.healthy():
                # the pod is DEAD: nothing on it can ever finish.
                # Harvest every request it owns — running decodes
                # rewind to their last recovery-log checkpoint — and
                # re-deliver them to survivors
                if self.ccfg.crash_recovery:
                    lost = eng.sched.crash_takeover(self.clock.now)
                    self.gateway.note_failure(eid, "crash")
                    self.crash_recovered += [r.request_id for r in lost]
            elif eng is not None:
                # degraded but alive: graceful drain — in-flight work
                # finishes here, only queued work is re-routed
                lost = eng.sched.takeover_waiting()
            self.pool_mgr.remove_engine(eid)
            if self.lora_ctrl is not None:
                self.lora_ctrl.remove_pod(eid)
            self._spawn_engine(ready=False, role=src_pool)
            self._redeliver_lost(lost, src_pool)

    def _redeliver_lost(self, reqs: List, src_pool: str,
                        exclude=frozenset()) -> None:
        """Re-deliver harvested requests through the role pools,
        request by request; anything undeliverable right now (the
        replacement is still cold-starting and no other member can
        take it) retries on a timer instead of being dropped."""
        pending = []
        for r in reqs:
            try:
                if src_pool == "decode":
                    self.pool_mgr.handoff(r, exclude=exclude)
                else:
                    self.pool_mgr.submit(r, exclude=exclude)
            except RuntimeError:
                pending.append(r)
        if pending:
            self.loop.after(1.0, lambda: self._redeliver_lost(
                pending, src_pool, exclude))

    # ------------------------------------------------------------ chaos
    def _busiest_engine(self) -> Optional[str]:
        live = sorted(e for e in self.engines
                      if e in self.gateway.engines
                      and self.engines[e].healthy())
        if not live:
            return None
        return max(live, key=lambda e: (
            self.engines[e].metrics().num_running
            + self.engines[e].metrics().num_waiting))

    def _chaos_exec(self, ev) -> None:
        now = self.clock.now
        self.chaos_log.append((now, ev.kind, ev.target))
        if ev.kind == "engine_crash":
            eid = ev.target or self._busiest_engine()
            eng = self.engines.get(eid)
            if eng is None:
                return
            # the process is gone mid-decode: heartbeat disappears from
            # telemetry (detection), iteration stops (effect).  Every
            # request aboard is recorded so benches can report the
            # resumed-request latency across recovery modes.
            sched = eng.sched
            self.crashed_requests += [
                r.request_id for r in (sched.waiting + sched.prefills
                                       + sched.running)]
            self.injector.inject(eid, FaultKind.DEVICE_LOST, now)
            eng.alive = False
        elif ev.kind == "straggler":
            eid = ev.target or self._busiest_engine()
            if eid not in self.engines:
                return
            self.injector.inject(eid, ev.fault, now,
                                 severity=ev.severity)
            if ev.duration > 0:
                self.loop.after(ev.duration, lambda: self.injector.clear(
                    eid, ev.fault))
        elif ev.kind == "kv_partition":
            if self.kv_pool is not None:
                self.kv_pool.partition(now, ev.duration or 1.0)
        elif ev.kind == "gateway_restart":
            self._gateway_restart(ev.duration or 1.0)

    def _gateway_restart(self, duration: float) -> None:
        """Bounce the gateway: dispatches arriving inside the window
        are deferred (client retries), and the restarted process comes
        back with its warm state — routing-policy EWMAs/affinity,
        rate-limit buckets, cordon set — wiped."""
        now = self.clock.now
        self.gw_restarts += 1
        self._gateway_down_until = max(self._gateway_down_until,
                                       now + duration)

        def back_up():
            gw = self.gateway
            gw.set_policy(self.ccfg.routing_policy, **self.ccfg.routing_kw)
            gw.clear_user_buckets()
            gw.cordoned.clear()
        self.loop.after(duration, back_up)

    def _hedge(self) -> None:
        """Straggler hedging: pull queued work off engines whose
        windowed tokens/s fell below hedge_ratio x the fleet median
        and re-route it to faster members (the straggler keeps its
        in-flight work — only NOT-yet-started requests move).
        Quarantined engines count too: cordoning stops NEW routing but
        would otherwise strand whatever was already queued on the slow
        node for its whole (slow) drain."""
        suspects = list(self.gateway.straggler_engines(
            self.ccfg.hedge_ratio))
        suspects += [e for e in self.gateway.cordoned
                     if e not in suspects]
        for eid in suspects:
            eng = self.engines.get(eid)
            if eng is None or not eng.sched.waiting:
                continue
            role = self.pool_mgr.role_of(eid)
            src_pool = role if role in self.pool_mgr.POOLS else "mixed"
            # hedging needs somewhere else to put the work
            others = (self.pool_mgr.decoders() if src_pool == "decode"
                      else self.pool_mgr.frontends())
            if len(others) - (eid in others) < 1:
                continue
            reqs = eng.sched.takeover_waiting()
            self.hedged += len(reqs)
            self.gateway.note_failure(eid, "hedged")
            self._redeliver_lost(reqs, src_pool, exclude={eid})

    def _promote_poll(self) -> None:
        """Drain due predictive promotions from the gateway's session
        shards and ask each session's pinned engine to prefetch its SSD
        pages into host DRAM (the promoter runs between turns — off
        every request's critical path)."""
        for sid, eid in self.gateway.due_promotions(self.clock.now):
            eng = self.engines.get(eid)
            if eng is not None and eng.healthy():
                if eng.promote_session(sid):
                    self.promotions += 1

    def _lora_replan(self) -> None:
        """Demand-driven replanning: fold gateway-observed per-adapter
        rates into the registry and drive live register/unregister on
        healthy pods (engines defer unloads of in-flight adapters)."""
        live = {eid: self.engines[eid] for eid in self.engines
                if eid in self.gateway.engines
                and self.engines[eid].healthy()}
        self.lora_ctrl.refresh_demand(self.clock.now)
        self.lora_ctrl.sync(live)

    def _autoscale(self) -> None:
        asc = self.ccfg.autoscaler
        if asc is None:
            return
        now = self.clock.now
        decision = asc.desired(now, self.metrics, self.active_replicas)
        desired = decision.desired
        if self.lora_ctrl is not None:
            # adapter-count-aware floor: scale-in may never strand
            # registered adapters without a slot to be served from
            desired = max(desired, min(
                self.lora_ctrl.desired_pods(self._lora_slots),
                asc.max_replicas))
        self.scale_history.append((now, self.active_replicas, desired))
        delta = desired - self.active_replicas
        for _ in range(max(delta, 0)):
            # reuse a warm spare if one exists, else cold-start a new pod
            spare = [e for e in self.engines
                     if e not in self.gateway.engines
                     and self.engines[e].healthy()]
            if spare:
                # rejoin through the pool manager so pool membership
                # and gateway registration stay consistent (the retire
                # path removes from both)
                self.pool_mgr.add_engine(spare[0], self.engines[spare[0]],
                                         "mixed")
            else:
                self._spawn_engine(ready=False)
        for _ in range(max(-delta, 0)):
            self._retire_engine()

    # ------------------------------------------------------------ run
    def run(self, workload: Iterable[TimedRequest],
            drain_s: float = 600.0) -> dict:
        """Drive a workload to completion and return :meth:`summary`.

        ``workload`` may be a list (every arrival scheduled up front,
        the historical behavior) or any time-ordered iterator such as
        :func:`~repro.core.sim.workloads.multi_round_qa` — iterators
        are consumed lazily, one pending arrival at a time, so a
        million-session trace never materializes in memory."""
        self._last_arrival = 0.0
        self._exhausted = False
        if isinstance(workload, (list, tuple)):
            for tr in workload:
                self._ingest(tr)
                self.loop.schedule(tr.arrival, self._make_dispatch(tr))
            self._last_arrival = (workload[-1].arrival if workload
                                  else 0.0)
            self._exhausted = True
        else:
            self._feed(iter(workload))
        # the scrape pump exists to feed the autoscaler's MetricStore
        # and the telemetry->diagnosis path (chaos forces telemetry
        # on); with neither consumer it's pure O(fleet x sim-seconds)
        # overhead per run, so don't schedule it
        if self.ccfg.autoscaler is not None or self.ccfg.telemetry:
            self.loop.every(self.ccfg.scrape_period_s, self._scrape)
        if self.ccfg.chaos is not None:
            for ev in self.ccfg.chaos:
                self.loop.schedule(ev.at, (lambda e=ev:
                                           self._chaos_exec(e)))
        if self.ccfg.hedge_ratio > 0:
            self.loop.every(self.ccfg.hedge_period_s, self._hedge)
        if (self.ccfg.promote_lead_s > 0
                and self.ccfg.routing_policy == "session"):
            self.loop.every(self.ccfg.promote_poll_period_s,
                            self._promote_poll)
        if self.ccfg.autoscaler is not None:
            self.loop.every(self.ccfg.autoscale_period_s, self._autoscale)
        if self.lora_ctrl is not None:
            self.loop.every(self.ccfg.lora_replan_period_s,
                            self._lora_replan)
        if self.disaggregated:
            self.loop.every(self.ccfg.pool_poll_period_s,
                            lambda: self.pool_mgr.poll(self.clock.now))
        if self.rebalancer is not None:
            self.loop.every(
                self.rebalancer.cfg.period_s,
                lambda: self.rebalancer.step(self.clock.now,
                                             self.pool_mgr))
        def done() -> bool:
            if not self._exhausted:
                return False
            if self.clock.now > self._last_arrival + drain_s:
                return True
            if self.clock.now <= self._last_arrival:
                return False
            if self._busy_engines > 0:
                # some engine has an iteration pending: certainly not
                # done, no need to touch the fleet (the hot path at
                # million-session scale)
                return False
            return not any(e.has_work for e in self.engines.values())

        # iterator workloads have no a-priori end time: the done()
        # predicate (checked after every event) supplies the cap once
        # the source runs dry
        end = (self._last_arrival + drain_s
               if isinstance(workload, (list, tuple)) else float("inf"))
        self.loop.run(until=end, stop_when=done)
        return self.summary()

    def _ingest(self, tr: TimedRequest) -> None:
        if self.ccfg.retain_requests:
            self.all_requests.append(tr.request)

    def _feed(self, it) -> None:
        """Pull ONE workload item and schedule its dispatch; the next
        pull rides on that dispatch event (arrivals are time-ordered,
        so at most one undelivered arrival is ever in the heap)."""
        tr = next(it, None)
        if tr is None:
            self._exhausted = True
            return
        self._ingest(tr)
        self._last_arrival = tr.arrival
        dispatch = self._make_dispatch(tr)

        def fire():
            dispatch()
            self._feed(it)
        self.loop.schedule(tr.arrival, fire)

    def _make_dispatch(self, tr: TimedRequest) -> Callable:
        def dispatch():
            if self.clock.now < self._gateway_down_until:
                # gateway mid-restart: the client retries shortly
                # after the downtime window ends
                self.gw_deferred += 1
                self.loop.after(
                    (self._gateway_down_until - self.clock.now)
                    + self.ccfg.gw_retry_delay_s, dispatch)
                return
            eid = self.gateway.route(
                tr.request.prompt_tokens, user=tr.request.user,
                lora_adapter=tr.request.lora_adapter,
                est_output_tokens=tr.request.sampling.max_new_tokens,
                priority_class=tr.request.priority_class,
                session_id=tr.request.session_id)
            if eid is None:
                self.rejected += 1
                return
            self.engines[eid].submit(tr.request)
        return dispatch

    def summary(self) -> dict:
        s = (self.stream_summary.summary()
             if self.stream_summary is not None
             else summarize(self.all_requests))
        s["rejected"] = self.rejected
        s["sim_events"] = self.loop.events_fired
        # loud load shedding: surface the gateway's rate-limit drops in
        # every cluster summary so benches can't under-report load
        s["shed_requests"] = self.gateway.stats.shed
        s["routing_policy"] = self.ccfg.routing_policy
        if self.gateway.num_shards > 1:
            s["gateway_shards"] = self.gateway.num_shards
        ss = self.gateway.session_stats()
        if ss is not None:
            s["session_hits"] = ss["session_hits"]
            s["session_misses"] = ss["session_misses"]
            s["session_rehomed"] = ss["session_rehomed"]
            if ss["promote_skipped"]:
                s["promote_skipped"] = ss["promote_skipped"]
        if self.kv_pool is not None:
            st = self.kv_pool.stats
            s["pool_hits"] = st.hits_local + st.hits_remote
            s["pool_evictions"] = st.evictions
            s["pool_dup_drops"] = st.dup_puts_dropped
            s["pool_fetch_failures"] = st.fetch_failures
            s["pool_publish_failures"] = st.publish_failures
        agg = [e.metrics() for e in self.engines.values()]
        s["prefix_hit_tokens"] = sum(m.prefix_hit_tokens for m in agg)
        s["remote_hit_tokens"] = sum(m.remote_hit_tokens for m in agg)
        s["preemptions"] = sum(m.preemptions for m in agg)
        # tiered-KV pressure: host/SSD-tier hits, swap traffic, wire bytes
        s["host_hit_tokens"] = sum(m.host_hit_tokens for m in agg)
        s["ssd_hit_tokens"] = sum(m.ssd_hit_tokens for m in agg)
        s["ssd_cross_hit_tokens"] = sum(m.ssd_cross_hit_tokens
                                        for m in agg)
        s["promote_hits"] = sum(m.promote_hits for m in agg)
        s["promote_wasted"] = sum(m.promote_wasted for m in agg)
        if self.promotions:
            s["promotions"] = self.promotions
        # SSD tier accounting (pool-level so shared pools count once):
        # write-behind drops are a first-class signal, and the shared
        # pool's dedupe ratio is the cross-engine sharing payoff
        pools = self.ssd_pools()
        if pools:
            s["ssd_puts"] = sum(p.stats.puts for p in pools)
            s["ssd_bytes_written"] = sum(p.stats.bytes_written
                                         for p in pools)
            s["ssd_dropped_puts"] = sum(p.stats.dropped_puts
                                        for p in pools)
        if self._host_ssd:
            dp = sum(p.dedup_puts for p in self._host_ssd.values())
            tp = sum(p.stats.puts for p in self._host_ssd.values())
            s["ssd_dedup_puts"] = dp
            s["ssd_dedup_bytes"] = sum(p.dedup_bytes
                                       for p in self._host_ssd.values())
            s["ssd_dedupe_ratio"] = dp / max(tp + dp, 1)
        s["swap_out"] = sum(m.swap_out for m in agg)
        s["swap_in"] = sum(m.swap_in for m in agg)
        s["kv_bytes_offloaded"] = sum(m.kv_bytes_offloaded for m in agg)
        s["kv_bytes_fetched"] = sum(m.kv_bytes_fetched for m in agg)
        # failure handling: drop-and-recompute waste, pool-failure
        # fallbacks and the recovery log's footprint
        s["wasted_tokens"] = sum(m.wasted_tokens for m in agg)
        s["kv_fetch_failures"] = sum(m.kv_fetch_failures for m in agg)
        s["ckpt_pages"] = sum(m.ckpt_pages for m in agg)
        # multi-LoRA serving: routing affinity + adapter-tier churn
        if self.lora_ctrl is not None or self.gateway.stats.lora_routed:
            s["lora_routed"] = self.gateway.stats.lora_routed
            s["lora_affinity_hit_rate"] = \
                self.gateway.stats.lora_affinity_hit_rate
            s["lora_miss"] = sum(m.lora_miss for m in agg)
            s["lora_shed"] = sum(m.lora_shed for m in agg)
            s["lora_cold_loads"] = sum(m.lora_cold_loads for m in agg)
            s["lora_cold_load_s"] = sum(m.lora_cold_load_s for m in agg)
            s["lora_evictions"] = sum(m.lora_evictions for m in agg)
        if self.lora_ctrl is not None:
            s["lora_ctrl_loads"] = self.lora_ctrl.stats["loads"]
            s["lora_ctrl_unloads"] = self.lora_ctrl.stats["unloads"]
        if self.ccfg.telemetry or self.ccfg.chaos is not None:
            s["diagnoses"] = len(self.diagnoses)
            s["quarantines"] = self.quarantines
            s["readmits"] = self.readmits
            s["crashed_requests"] = len(self.crashed_requests)
            s["crash_recovered"] = len(self.crash_recovered)
            s["hedged"] = self.hedged
            s["gw_restarts"] = self.gw_restarts
            s["gw_deferred"] = self.gw_deferred
        if self.disaggregated:
            s["pool_counts"] = {p: len(m)
                                for p, m in self.pool_mgr.pools.items()
                                if m}
            s["migrations"] = len(self.pool_mgr.migrations)
            att = self.pool_mgr.attainment()
            s["pool_ttft_attainment"] = att["ttft"]
            s["pool_itl_attainment"] = att["itl"]
        return s
