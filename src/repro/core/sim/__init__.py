from repro.core.sim.cluster_sim import ClusterConfig, ServingCluster  # noqa: F401
from repro.core.sim.events import EventLoop, SimClock  # noqa: F401
from repro.core.sim.sim_engine import SimEngine, SimEngineConfig  # noqa: F401
from repro.core.sim import workloads  # noqa: F401
