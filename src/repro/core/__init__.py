# AIBrix core: the paper's system-level contribution in composable modules.
