"""Role-aware control plane: dynamic P/D pools with live migration.

Serving roles are a first-class control-plane concept here instead of a
launch-time constant: :class:`RolePoolManager` owns named pools
(``prefill`` / ``decode`` / ``mixed``) of engines, exposes per-pool
depth and fleet SLO attainment, load-balances the prefill->decode
handoff, and supports **live role migration** — draining a member
(stop admitting, finish in-flight chunks, hand its queued work to the
other pool members) and re-registering it under the other role, so the
P:D ratio changes without restarts.  The same manager drives the real
JAX engines (``launch/serve.py --roles auto``) and the discrete-event
cluster simulator (``ServingCluster``), because both engine shapes
expose the shared ``Scheduler`` the drain protocol talks to.

:class:`AttainmentRebalancer` closes the loop the paper's SLO-driven
GPU optimizer opens: one inverted-metric autoscaler instance per pool
(the PR-3 machinery — pressure = miss-rate over the allowed miss
budget), with **TTFT attainment sizing the prefill pool** and **ITL
attainment sizing the decode pool**.  TTFT misses mean prompts queue
for prefill capacity; ITL misses mean decode batches are over-packed —
so at fixed fleet size a deficit in one pool is served by migrating a
member from the other (``repro.core.optimizer.split_roles`` proposes
the *initial* ratio from the roofline profile; this adapts it live).

Engines are anything exposing ``sched`` (the shared Scheduler),
``submit(req)``, ``metrics()`` and ``has_work`` — the real
``InferenceEngine`` and the simulator's ``SimEngine`` both qualify.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.autoscaler.metrics import MetricStore
from repro.core.autoscaler.policies import make_autoscaler
from repro.engine.request import Request
from repro.engine.scheduler import DECODER_ROLES, FRONTEND_ROLES


def parse_role_spec(spec: str, default_engines: int) -> List[str]:
    """'mixed' -> N mixed engines; '2P2D'/'1p3d' -> disaggregated.

    ``'auto'`` is a control-plane decision, not a parse: resolve it
    first (``repro.core.optimizer.split_roles`` or an even split) and
    pass the concrete spec here.
    """
    if not spec or spec == "mixed":
        return ["mixed"] * default_engines
    m = re.fullmatch(r"(\d+)[pP](\d+)[dD]", spec)
    if m is None:
        raise ValueError(
            f"role spec {spec!r}: expected 'mixed' or '<n>P<m>D'")
    n_p, n_d = int(m.group(1)), int(m.group(2))
    if n_p == 0 or n_d == 0:
        raise ValueError(
            f"role spec {spec!r}: a disaggregated group needs at least "
            "one prefill AND one decode engine")
    return ["prefill"] * n_p + ["decode"] * n_d


@dataclass
class Migration:
    """One live role change, from drain request to completion."""
    engine_id: str
    src: str
    dst: str
    started: float
    completed: float = -1.0          # -1 while still draining

    @property
    def done(self) -> bool:
        return self.completed >= 0.0


class RolePoolManager:
    """Named engine pools + the live role-migration protocol.

    Migration protocol (P->D; D->P is symmetric):

    1. ``request_migration`` marks the member ``draining``: the shared
       Scheduler stops admitting, the gateway stops routing to it, and
       its not-yet-admitted queue is re-delivered to the remaining pool
       members (prefill-pool waiters need prefilling -> frontends;
       decode-pool waiters already have KV in the distributed pool ->
       other decoders).
    2. In-flight work finishes normally: a draining prefill member
       completes its chunks and hands each request off through the
       existing ``DistributedKVPool`` path; a draining decode member
       finishes its running decodes.
    3. ``poll`` observes the drain completing, flips the scheduler role
       (``Scheduler.set_role``) and re-registers the member under its
       new pool — no restart, no engine state rebuilt.
    """

    POOLS = ("prefill", "decode", "mixed")
    FRONTEND_POOLS = FRONTEND_ROLES          # admit NEW requests
    DECODER_POOLS = DECODER_ROLES            # accept handoffs

    def __init__(self, clock: Callable[[], float] = None, gateway=None):
        self.clock = clock or (lambda: 0.0)
        self.gateway = gateway
        self.pools: Dict[str, Dict[str, object]] = {
            p: {} for p in self.POOLS}
        self._engines: Dict[str, object] = {}
        self._draining: Dict[str, Migration] = {}
        self.migrations: List[Migration] = []    # completed, in order

    # ------------------------------------------------------------ members
    def add_engine(self, engine_id: str, engine, role: str = "mixed"
                   ) -> None:
        if role not in self.POOLS:
            raise ValueError(f"unknown pool {role!r}: {self.POOLS}")
        sched = getattr(engine, "sched", None)
        if sched is not None:
            if sched.scfg.role != role:
                sched.set_role(role)
            # every member gets the load-balancing handoff shim; it
            # only fires while the member's role is 'prefill'
            sched.handoff = self.handoff
        self.pools[role][engine_id] = engine
        self._engines[engine_id] = engine
        if self.gateway is not None:
            self.gateway.register_engine(engine_id, engine, pool=role)

    def remove_engine(self, engine_id: str) -> None:
        self._engines.pop(engine_id, None)
        self._draining.pop(engine_id, None)
        for members in self.pools.values():
            members.pop(engine_id, None)
        if self.gateway is not None:
            self.gateway.deregister_engine(engine_id)

    def role_of(self, engine_id: str) -> Optional[str]:
        for pool, members in self.pools.items():
            if engine_id in members:
                return pool
        if engine_id in self._draining:
            return "draining"
        return None

    def members(self, pool: str) -> Dict[str, object]:
        return dict(self.pools[pool])

    def counts(self) -> Dict[str, int]:
        c = {p: len(m) for p, m in self.pools.items()}
        c["draining"] = len(self._draining)
        return c

    @property
    def engines(self) -> Dict[str, object]:
        return dict(self._engines)

    @property
    def draining(self) -> bool:
        return bool(self._draining)

    def _healthy(self, eng) -> bool:
        fn = getattr(eng, "healthy", None)
        return fn() if callable(fn) else True

    def frontends(self) -> Dict[str, object]:
        """Members that admit NEW requests (draining members excluded)."""
        return {eid: e for pool in self.FRONTEND_POOLS
                for eid, e in self.pools[pool].items()
                if self._healthy(e)}

    def decoders(self) -> Dict[str, object]:
        """Members that accept prefill handoffs."""
        return {eid: e for pool in self.DECODER_POOLS
                for eid, e in self.pools[pool].items()
                if self._healthy(e)}

    # ------------------------------------------------------------ signals
    @staticmethod
    def _queue_depth(engine) -> int:
        """O(1) queue-depth probe for the per-request submit/handoff
        hot path: read the shared scheduler's queues directly instead
        of building a full EngineMetrics snapshot (which scans the
        SLO attainment windows)."""
        sched = getattr(engine, "sched", None)
        if sched is not None:
            return (len(sched.waiting) + len(sched.running)
                    + len(sched.prefills))
        m = engine.metrics()
        return m.num_running + m.num_waiting

    @staticmethod
    def _waiting(engine) -> int:
        sched = getattr(engine, "sched", None)
        if sched is not None:
            return len(sched.waiting)
        return engine.metrics().num_waiting

    def depth(self, pool: str) -> int:
        """Total queue depth (running + waiting) across a pool."""
        return sum(self._queue_depth(e) for e in self.pools[pool].values())

    def waiting_depth(self, pool: str) -> int:
        """Waiting-only depth: work QUEUED (not being served) in a
        pool.  Prefill-side waiters mean prefill capacity is the
        bottleneck; decode-side waiters mean handed-off requests are
        blocked on decode slots — the disambiguator for TTFT misses,
        which span both pools."""
        return sum(self._waiting(e) for e in self.pools[pool].values())

    def attainment(self, focus_class: Optional[str] = None
                   ) -> Dict[str, float]:
        """Fleet-aggregated windowed SLO attainment.  Finishes happen on
        decode/mixed members, but the attribution is causal across
        pools: TTFT covers the prefill queue + handoff, ITL the decode
        step time — so ``ttft`` sizes the prefill pool and ``itl`` the
        decode pool.  ``focus_class`` narrows the TTFT signal to one
        priority class's windowed attainment (e.g. 'interactive' — the
        class whose SLO the rebalance is protecting); ITL stays the
        fleet-wide windowed figure, which the focus class dominates
        whenever it is the decode-latency-sensitive one."""
        ttft, itl = [], []
        for eng in self._engines.values():
            m = eng.metrics()
            if not m.finished_requests:
                continue
            t_att = m.slo_attainment
            if focus_class is not None:
                for name, ttft_att, _itl_att, _n in m.slo_by_class:
                    if name == focus_class:
                        t_att = ttft_att
                        break
            ttft.append(t_att)
            itl.append(m.slo_itl_attainment)
        return {"ttft": sum(ttft) / len(ttft) if ttft else 1.0,
                "itl": sum(itl) / len(itl) if itl else 1.0}

    # ------------------------------------------------------------ data path
    def handoff(self, req: Request, exclude=()) -> None:
        """Prefill->decode handoff: least-loaded decoder by queue depth.
        ``exclude`` removes members from consideration (hedging away
        from a straggler, re-delivery off a crashed engine)."""
        targets = {eid: e for eid, e in self.decoders().items()
                   if eid not in exclude}
        if not targets:
            raise RuntimeError("role pools: handoff with no decode-"
                               "capable member (refused to drain last?)")
        eid = min(sorted(targets), key=lambda e: self._queue_depth(
            targets[e]))
        targets[eid].submit(req)

    def submit(self, req: Request, exclude=()) -> None:
        """Admit a NEW request: least-loaded frontend by queue depth
        (what the gateway's least-request policy computes; this is the
        manager-local path used for drain re-delivery and tests).
        ``exclude`` as in :meth:`handoff`."""
        targets = {eid: e for eid, e in self.frontends().items()
                   if eid not in exclude}
        if not targets:
            raise RuntimeError("role pools: no frontend member")
        eid = min(sorted(targets), key=lambda e: self._queue_depth(
            targets[e]))
        targets[eid].submit(req)

    def _redeliver(self, reqs: List[Request], src_pool: str,
                   exclude=()) -> None:
        for r in reqs:
            if src_pool == "decode":
                # KV already in the distributed pool
                self.handoff(r, exclude=exclude)
            else:
                self.submit(r, exclude=exclude)

    # ------------------------------------------------------------ migration
    def request_migration(self, src: str, dst: str, now: float,
                          engine_id: Optional[str] = None
                          ) -> Optional[Migration]:
        """Begin draining one ``src``-pool member toward ``dst``.

        Picks the least-loaded member unless ``engine_id`` pins one.
        Refuses moves that would leave a disaggregated topology without
        a frontend or without a decoder.  Returns the in-flight
        :class:`Migration` (or None if refused)."""
        if src not in self.POOLS or dst not in self.POOLS or src == dst:
            raise ValueError(f"bad migration {src!r}->{dst!r}")
        candidates = self.pools[src]
        if engine_id is not None:
            if engine_id not in candidates:
                return None
        elif candidates:
            engine_id = min(sorted(candidates), key=lambda e:
                            self._queue_depth(candidates[e]))
        if engine_id is None:
            return None
        # liveness: never drain the last frontend or the last decoder
        if src in self.FRONTEND_POOLS and \
                len(self.frontends()) - (engine_id in self.frontends()) < 1:
            return None
        if src in self.DECODER_POOLS and \
                len(self.decoders()) - (engine_id in self.decoders()) < 1:
            return None
        engine = candidates.pop(engine_id)
        mig = Migration(engine_id, src, dst, started=now)
        self._draining[engine_id] = mig
        sched = getattr(engine, "sched", None)
        if sched is not None:
            sched.draining = True
            self._redeliver(sched.takeover_waiting(), src)
        if self.gateway is not None:
            self.gateway.set_engine_pool(engine_id, "draining")
        return mig

    def poll(self, now: float) -> List[Migration]:
        """Advance in-flight migrations; returns those that completed.
        Call this from the serving loop (real engines) or a periodic
        event (the simulator) — draining is asynchronous by design."""
        done: List[Migration] = []
        for eid, mig in list(self._draining.items()):
            engine = self._engines[eid]
            sched = engine.sched
            if sched.waiting:        # raced submit: re-deliver and wait
                self._redeliver(sched.takeover_waiting(), mig.src)
            if not sched.drained:
                continue
            sched.set_role(mig.dst)
            sched.draining = False
            del self._draining[eid]
            self.pools[mig.dst][eid] = engine
            mig.completed = now
            self.migrations.append(mig)
            done.append(mig)
            if self.gateway is not None:
                self.gateway.set_engine_pool(eid, mig.dst)
        return done


@dataclass
class RebalanceConfig:
    """Knobs for the attainment-driven pool-sizing loop."""
    ttft_target: float = 0.90        # prefill-pool attainment target
    itl_target: float = 0.90         # decode-pool attainment target
    period_s: float = 5.0            # decision cadence
    # min spacing between migrations: at least the scheduler-core SLO
    # window, so each move's effect is measured on fresh finishes
    # before the next move can act on the same (stale) misses
    cooldown_s: float = 60.0
    warmup_s: float = 30.0           # no moves before the attainment
    #                                  window has real finishes in it
    min_prefill: int = 1
    min_decode: int = 1
    scaler: str = "apa"              # per-pool autoscaler policy
    scaler_kw: dict = field(default_factory=dict)
    # priority class whose windowed attainment drives the loop (None =
    # fleet-wide across classes); 'interactive' protects the tight SLO
    signal_class: Optional[str] = None


class AttainmentRebalancer:
    """One autoscaler instance per pool, attainment as the signal.

    Reuses the inverted-metric machinery verbatim: the prefill pool's
    instance targets windowed fleet TTFT attainment, the decode pool's
    targets windowed ITL attainment; each computes a desired member
    count for ITS pool independently.  At fixed fleet size the pool
    with the larger deficit pulls a member from the other via
    ``RolePoolManager.request_migration`` (one drain in flight at a
    time, rate-limited by ``cooldown_s``)."""

    METRICS = {"prefill": "pool_ttft_attainment",
               "decode": "pool_itl_attainment"}

    def __init__(self, cfg: Optional[RebalanceConfig] = None):
        self.cfg = cfg or RebalanceConfig()
        self.store = MetricStore()
        targets = {"prefill": self.cfg.ttft_target,
                   "decode": self.cfg.itl_target}
        self.scalers = {
            pool: make_autoscaler(self.cfg.scaler, metric=metric,
                                  target=targets[pool], min_replicas=1,
                                  **self.cfg.scaler_kw)
            for pool, metric in self.METRICS.items()}
        self._last_move = -1e18
        self._last_dir: Optional[str] = None
        self.history: List[tuple] = []   # (t, ttft, itl, n_p, n_d, want_p, want_d)

    def desired(self, now: float, manager: RolePoolManager
                ) -> Dict[str, int]:
        """Per-pool desired member counts — independent decisions."""
        return {pool: self.scalers[pool].desired(
            now, self.store, max(len(manager.pools[pool]), 1)).desired
            for pool in self.METRICS}

    def step(self, now: float, manager: RolePoolManager
             ) -> Optional[Migration]:
        """One reconcile tick: record signals, advance drains, maybe
        start one migration.  Returns the migration started (if any)."""
        att = manager.attainment(focus_class=self.cfg.signal_class)
        self.store.record(now, "pool_ttft_attainment", att["ttft"])
        self.store.record(now, "pool_itl_attainment", att["itl"])
        manager.poll(now)
        cur_p = len(manager.pools["prefill"])
        cur_d = len(manager.pools["decode"])
        if cur_p == 0 and cur_d == 0:
            return None              # colocated fleet: nothing to size
        want = self.desired(now, manager)
        self.history.append((now, att["ttft"], att["itl"], cur_p, cur_d,
                             want["prefill"], want["decode"]))
        if manager.draining or now < self.cfg.warmup_s:
            return None              # one drain at a time
        deficit_p = want["prefill"] - cur_p
        deficit_d = want["decode"] - cur_d
        # TTFT spans both pools (prefill queue + pool handoff + decode
        # admission + tail recompute), so a TTFT-attainment deficit is
        # only a PREFILL deficit when the backlog actually sits on the
        # prefill side — when the waiting queue has clearly piled up
        # behind the decode slots instead, reassign the deficit to the
        # decode pool (ITL misses need no such correction: they are
        # decode's alone).
        if deficit_p > 0 and cur_p and cur_d:
            wq_p = manager.waiting_depth("prefill") / cur_p
            wq_d = manager.waiting_depth("decode") / cur_d
            if wq_d > max(2.0 * wq_p, 2.0):
                deficit_d = max(deficit_d, deficit_p)
                deficit_p = 0
        direction = None
        if deficit_p > max(deficit_d, 0) and cur_d > self.cfg.min_decode:
            direction = "toward_prefill"
        elif deficit_d > max(deficit_p, 0) and cur_p > self.cfg.min_prefill:
            direction = "toward_decode"
        if direction is None:
            return None
        # direction-aware cooldown: REVERSING a move must wait out the
        # full attainment window (the misses that drove the last move
        # are still in it), but repeating the same direction on a
        # persistent deficit only needs half — the signal is fresh
        wait = (self.cfg.cooldown_s if direction != self._last_dir
                else self.cfg.cooldown_s / 2)
        if now - self._last_move < wait:
            return None
        if direction == "toward_prefill":
            mig = manager.request_migration("decode", "prefill", now)
        else:
            mig = manager.request_migration("prefill", "decode", now)
        if mig is not None:
            self._last_move = now
            self._last_dir = direction
        return mig
