"""Mixed-grain multi-node orchestration (paper §3.2.6, Figure 6).

Coarse grain (the Kubernetes role): ``ClusterManager`` owns pod
lifecycle — scheduling onto nodes, cold-start transitions
(PENDING -> PULLING -> LOADING -> READY), termination, and replica
reconciliation driven by the autoscaler's desired counts.

Fine grain (the Ray role): ``EngineGroup`` (= RayClusterFleet) binds
several pods into one logical multi-node engine (a head + workers, e.g.
TP across hosts for a 236B model), with group-atomic readiness and
rolling upgrades that never take more than ``max_unavailable`` groups
down — the service-oriented behavior the paper says raw engine-native
distribution lacks.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional

from repro.core.runtime.sidecar import ColdStartManager


class PodState(Enum):
    PENDING = "pending"
    PULLING = "pulling"       # artifact fetch
    LOADING = "loading"       # weights -> accelerator
    READY = "ready"
    TERMINATING = "terminating"
    FAILED = "failed"


@dataclass
class Pod:
    pod_id: str
    model: str
    device_type: str
    node: str
    state: PodState = PodState.PENDING
    ready_at: float = 0.0
    created_at: float = 0.0
    version: str = "v1"
    group: Optional[str] = None
    engine: object = None           # attached handle once READY


@dataclass
class Node:
    node_id: str
    device_type: str
    num_devices: int = 8
    used_devices: int = 0

    @property
    def free_devices(self) -> int:
        return self.num_devices - self.used_devices


class ClusterManager:
    """Coarse-grained resource manager (the Kubernetes role)."""

    def __init__(self, cold_start: ColdStartManager,
                 clock: Callable[[], float] = None,
                 devices_per_pod: int = 1,
                 engine_factory: Callable[[Pod], object] = None):
        self.cold = cold_start
        self.clock = clock or (lambda: 0.0)
        self.devices_per_pod = devices_per_pod
        self.engine_factory = engine_factory
        self.nodes: Dict[str, Node] = {}
        self.pods: Dict[str, Pod] = {}
        self._ids = itertools.count()
        self.events: List[tuple] = []      # (t, kind, pod_id)

    # ---------------------------------------------------------- nodes
    def add_node(self, node_id: str, device_type: str,
                 num_devices: int = 8) -> None:
        self.nodes[node_id] = Node(node_id, device_type, num_devices)

    # ---------------------------------------------------------- pods
    def create_pod(self, model: str, device_type: str,
                   version: str = "v1", group: Optional[str] = None
                   ) -> Optional[Pod]:
        """Schedule a pod onto the best node (cold-start aware)."""
        candidates = [n for n in self.nodes.values()
                      if n.device_type == device_type
                      and n.free_devices >= self.devices_per_pod]
        if not candidates:
            return None
        # fastest-artifact node first (ColdStartManager policy)
        best = self.cold.best_node(model, [n.node_id for n in candidates]) \
            if model in self.cold.artifacts else candidates[0].node_id
        node = self.nodes[best]
        node.used_devices += self.devices_per_pod
        now = self.clock()
        pod = Pod(pod_id=f"pod-{next(self._ids)}", model=model,
                  device_type=device_type, node=best, created_at=now,
                  version=version, group=group)
        cold_s = (self.cold.cold_start_s(model, best)
                  if model in self.cold.artifacts else 10.0)
        pod.state = PodState.PULLING
        pod.ready_at = now + cold_s
        self.pods[pod.pod_id] = pod
        self.events.append((now, "create", pod.pod_id))
        return pod

    def delete_pod(self, pod_id: str) -> None:
        pod = self.pods.pop(pod_id, None)
        if pod is None:
            return
        self.nodes[pod.node].used_devices -= self.devices_per_pod
        pod.state = PodState.TERMINATING
        self.events.append((self.clock(), "delete", pod_id))

    def fail_pod(self, pod_id: str) -> None:
        pod = self.pods.get(pod_id)
        if pod is not None:
            pod.state = PodState.FAILED
            self.events.append((self.clock(), "fail", pod_id))

    def tick(self) -> List[Pod]:
        """Advance lifecycle; returns pods that just became READY."""
        now = self.clock()
        became_ready = []
        for pod in self.pods.values():
            if pod.state in (PodState.PULLING, PodState.LOADING):
                # split cold window: first 70% pulling, rest loading
                if now >= pod.ready_at:
                    pod.state = PodState.READY
                    if self.engine_factory is not None:
                        pod.engine = self.engine_factory(pod)
                    became_ready.append(pod)
                    self.events.append((now, "ready", pod.pod_id))
                elif now >= pod.created_at + 0.7 * (pod.ready_at
                                                    - pod.created_at):
                    pod.state = PodState.LOADING
        return became_ready

    # ---------------------------------------------------------- reconcile
    def ready_pods(self, model: str, device_type: Optional[str] = None
                   ) -> List[Pod]:
        return [p for p in self.pods.values()
                if p.model == model and p.state == PodState.READY
                and (device_type is None or p.device_type == device_type)]

    def reconcile(self, model: str, device_type: str, desired: int) -> None:
        """Drive replica count toward ``desired`` (autoscaler actuation)."""
        alive = [p for p in self.pods.values()
                 if p.model == model and p.device_type == device_type
                 and p.state not in (PodState.TERMINATING, PodState.FAILED)]
        for _ in range(desired - len(alive)):
            self.create_pod(model, device_type)
        if desired < len(alive):
            # prefer terminating not-yet-ready pods, then newest
            order = sorted(alive, key=lambda p: (p.state == PodState.READY,
                                                 -p.created_at))
            for pod in order[:len(alive) - desired]:
                self.delete_pod(pod.pod_id)


@dataclass
class GroupSpec:
    name: str
    model: str
    device_type: str
    group_size: int          # pods per logical engine (head + workers)
    replicas: int
    version: str = "v1"


class EngineGroup:
    """Fine-grained orchestration: RayClusterFleet analogue.

    Each replica = ``group_size`` pods forming one logical multi-node
    engine; a replica is READY only when every member is.  Rolling
    upgrade replaces replicas version-by-version, keeping at least
    (replicas - max_unavailable) serving.
    """

    def __init__(self, spec: GroupSpec, cluster: ClusterManager,
                 max_unavailable: int = 1):
        self.spec = spec
        self.cluster = cluster
        self.max_unavailable = max_unavailable
        self.replica_pods: Dict[int, List[str]] = {}
        self._next_replica = 0

    def scale_to(self, replicas: int) -> None:
        while len(self.replica_pods) < replicas:
            rid = self._next_replica
            self._next_replica += 1
            pods = []
            for _ in range(self.spec.group_size):
                pod = self.cluster.create_pod(
                    self.spec.model, self.spec.device_type,
                    version=self.spec.version,
                    group=f"{self.spec.name}-{rid}")
                if pod is None:        # insufficient capacity: rollback
                    for pid in pods:
                        self.cluster.delete_pod(pid)
                    return
                pods.append(pod.pod_id)
            self.replica_pods[rid] = pods
        while len(self.replica_pods) > replicas:
            rid = max(self.replica_pods)
            for pid in self.replica_pods.pop(rid):
                self.cluster.delete_pod(pid)

    def replica_ready(self, rid: int) -> bool:
        return all(self.cluster.pods[p].state == PodState.READY
                   for p in self.replica_pods.get(rid, [])
                   if p in self.cluster.pods)

    def ready_replicas(self) -> List[int]:
        return [r for r in self.replica_pods if self.replica_ready(r)]

    def rolling_upgrade(self, new_version: str, tick_until) -> List[str]:
        """Upgrade every replica to ``new_version``; returns an event log.
        ``tick_until(pred)`` advances sim time until pred() is true."""
        log = []
        self.spec.version = new_version
        for rid in sorted(list(self.replica_pods)):
            old = self.replica_pods[rid]
            # never exceed max_unavailable: wait until enough are ready
            tick_until(lambda: len(self.ready_replicas())
                       >= len(self.replica_pods) - self.max_unavailable)
            pods = []
            ok = True
            for _ in range(self.spec.group_size):
                pod = self.cluster.create_pod(
                    self.spec.model, self.spec.device_type,
                    version=new_version, group=f"{self.spec.name}-{rid}")
                if pod is None:
                    ok = False
                    break
                pods.append(pod.pod_id)
            if not ok:
                for pid in pods:
                    self.cluster.delete_pod(pid)
                log.append(f"replica-{rid}: insufficient capacity, skipped")
                continue
            tick_until(lambda: all(
                self.cluster.pods[p].state == PodState.READY for p in pods))
            for pid in old:
                self.cluster.delete_pod(pid)
            self.replica_pods[rid] = pods
            log.append(f"replica-{rid}: upgraded to {new_version}")
        return log
