from repro.core.orchestration.cluster import (ClusterManager, EngineGroup,  # noqa: F401
                                              GroupSpec, Pod, PodState)
from repro.core.orchestration.pools import (AttainmentRebalancer,  # noqa: F401
                                            Migration, RebalanceConfig,
                                            RolePoolManager,
                                            parse_role_spec)
