from repro.core.orchestration.cluster import (ClusterManager, EngineGroup,  # noqa: F401
                                              GroupSpec, Pod, PodState)
