"""Accelerator catalogue + roofline-driven performance model.

The paper's GPU optimizer needs per-(device, model, workload-bucket)
throughput profiles.  The paper obtains them by offline benchmarking and
*suggests* (limitations section) replacing that with roofline-model
analysis (Imai et al. 2024) — we implement exactly that suggestion:
profiles are derived analytically from device peak FLOPs / HBM bandwidth
/ memory and the model's parameter & KV byte counts.  An offline-table
path (`ProfileTable.from_measurements`) is kept for parity with the
paper's original method.

Catalogue includes the paper's A10 / L20 / V100 plus TPU v5e (our
deployment target) so heterogeneous optimization covers both worlds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float          # bf16/fp16 dense, FLOP/s
    hbm_bw: float              # bytes/s
    hbm_bytes: float
    cost_per_hour: float       # $/h (typical cloud on-demand)
    mfu_prefill: float = 0.55  # achievable fraction of peak in prefill
    mbu_decode: float = 0.70   # achievable fraction of HBM bw in decode


DEVICES: Dict[str, DeviceSpec] = {
    "a10":    DeviceSpec("a10",    125e12, 600e9,  24e9, 0.75),
    "l20":    DeviceSpec("l20",    119.5e12, 864e9, 48e9, 1.40),
    "v100":   DeviceSpec("v100",   112e12, 900e9,  32e9, 2.20),
    "a100":   DeviceSpec("a100",   312e12, 2039e9, 80e9, 3.70),
    "tpu-v5e": DeviceSpec("tpu-v5e", 197e12, 819e9, 16e9, 1.20),
}


@dataclass(frozen=True)
class WorkloadBucket:
    """A (input_len, output_len) workload class (Mélange-style)."""
    in_len: int
    out_len: int

    @property
    def key(self) -> Tuple[int, int]:
        return (self.in_len, self.out_len)


class PerfModel:
    """Roofline performance model for one model on one device."""

    def __init__(self, cfg: ModelConfig, dev: DeviceSpec,
                 bytes_per_param: int = 2, kv_dtype_bytes: int = 2):
        self.cfg, self.dev = cfg, dev
        self.n_params = cfg.param_count()
        self.n_active = cfg.active_param_count()
        self.param_bytes = self.n_params * bytes_per_param
        # KV bytes per token (GQA; MLA uses the compressed latent)
        if cfg.mla is not None:
            per_layer = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
        else:
            per_layer = 2 * cfg.n_kv_heads * cfg.head_dim
        self.kv_bytes_per_token = per_layer * cfg.n_layers * kv_dtype_bytes

    def lora_adapter_bytes(self, rank: int,
                           bytes_per_param: int = 2) -> int:
        """Artifact size of one q/v LoRA adapter (the bank
        ``paged_model.init_lora`` holds: A_q/B_q down+up projections on
        the query heads, A_v/B_v on the KV heads) — what a cold load
        moves over the artifact tier and the host->device link."""
        cfg = self.cfg
        hd = cfg.head_dim or cfg.d_model // cfg.n_heads
        return bytes_per_param * (2 * cfg.d_model * rank
                                  + rank * cfg.n_heads * hd
                                  + rank * cfg.n_kv_heads * hd)

    def fits(self) -> bool:
        return self.param_bytes < self.dev.hbm_bytes * 0.9

    def max_batch(self, ctx_len: int) -> int:
        """KV-memory-limited concurrent sequences at context ctx_len."""
        free = self.dev.hbm_bytes * 0.9 - self.param_bytes
        per_seq = self.kv_bytes_per_token * max(ctx_len, 1)
        return max(int(free / per_seq), 0)

    def prefill_time(self, n_tokens: int) -> float:
        """Compute-bound prefill (s)."""
        flops = 2.0 * self.n_active * n_tokens
        return flops / (self.dev.peak_flops * self.dev.mfu_prefill)

    def decode_step_time(self, batch: int, ctx_len: int) -> float:
        """Bandwidth-bound decode iteration (s): weights read once per
        step + per-sequence KV read."""
        bytes_moved = (self.param_bytes
                       + batch * self.kv_bytes_per_token * ctx_len)
        t_mem = bytes_moved / (self.dev.hbm_bw * self.dev.mbu_decode)
        t_flops = (2.0 * self.n_active * batch
                   / (self.dev.peak_flops * self.dev.mfu_prefill))
        return max(t_mem, t_flops)

    def mixed_step_time(self, batch: int, ctx_len: float,
                        prefill_tokens: int) -> float:
        """Fused mixed-batch step (the engine's ``mixed_step``): B
        decode rows + prefill chunks flattened into ONE pass, so the
        weights stream once for the whole token batch while the decode
        rows add their per-sequence KV reads and the prefill tokens
        their FLOPs — one roofline over both.  Degenerates to
        ``decode_step_time`` at ``prefill_tokens=0``; with ``batch=0``
        it is a prefill chunk that also pays the weight stream."""
        flops = 2.0 * self.n_active * (batch + prefill_tokens)
        t_comp = flops / (self.dev.peak_flops * self.dev.mfu_prefill)
        bytes_moved = (self.param_bytes
                       + batch * self.kv_bytes_per_token * ctx_len)
        t_mem = bytes_moved / (self.dev.hbm_bw * self.dev.mbu_decode)
        return max(t_comp, t_mem)

    def spec_step_time(self, batch: int, ctx_len: float,
                       verify_tokens: int,
                       prefill_tokens: int = 0) -> float:
        """Speculative verification step: each decode row feeds its
        last token plus draft tokens through ONE pass.  The
        ``verify_tokens`` (total drafts across the batch) add FLOPs
        like prefill tokens but, crucially, NO extra byte traffic —
        the weights still stream once and the KV read is the same as a
        plain decode step — which is exactly why speculation wins on
        the bandwidth-bound decode roofline: the step emits
        ``1 + accepted`` tokens per row for (almost) the memory time
        of one.  Degenerates to ``mixed_step_time`` at
        ``verify_tokens=0``."""
        flops = 2.0 * self.n_active * (batch + verify_tokens
                                       + prefill_tokens)
        t_comp = flops / (self.dev.peak_flops * self.dev.mfu_prefill)
        bytes_moved = (self.param_bytes
                       + batch * self.kv_bytes_per_token * ctx_len)
        t_mem = bytes_moved / (self.dev.hbm_bw * self.dev.mbu_decode)
        return max(t_comp, t_mem)

    # ---------------------------------------------------- request level
    def request_time(self, bucket: WorkloadBucket, batch: int) -> float:
        """End-to-end time of one request at the given batching level."""
        ctx = bucket.in_len + bucket.out_len // 2
        return (self.prefill_time(bucket.in_len)
                + bucket.out_len * self.decode_step_time(batch, ctx))

    def ttft(self, bucket: WorkloadBucket, queue_depth: int = 0) -> float:
        return self.prefill_time(bucket.in_len) * (1 + queue_depth)

    def capacity_rps(self, bucket: WorkloadBucket,
                     slo_ttft_s: Optional[float] = None,
                     slo_itl_s: Optional[float] = None) -> float:
        """Max sustainable requests/s for this bucket under SLOs.

        Picks the best batch level that still meets ITL SLO; returns 0
        when the model doesn't fit or SLOs are unmeetable.
        """
        if not self.fits():
            return 0.0
        if slo_ttft_s is not None and \
                self.prefill_time(bucket.in_len) > slo_ttft_s:
            return 0.0
        ctx = bucket.in_len + bucket.out_len
        best = 0.0
        b_hi = max(self.max_batch(ctx), 0)
        for batch in (1, 2, 4, 8, 16, 32, 64):
            if batch > b_hi:
                break
            itl = self.decode_step_time(batch, ctx)
            if slo_itl_s is not None and itl > slo_itl_s:
                break
            t_req = self.request_time(WorkloadBucket(*bucket.key), batch)
            rps = batch / max(t_req, 1e-9)
            best = max(best, rps)
        return best


class ProfileTable:
    """(device, bucket) -> capacity rps, either analytic or measured."""

    def __init__(self, cfg: ModelConfig, slo_ttft_s: float = None,
                 slo_itl_s: float = None):
        self.cfg = cfg
        self.slo_ttft_s = slo_ttft_s
        self.slo_itl_s = slo_itl_s
        self._measured: Dict[Tuple[str, Tuple[int, int]], float] = {}

    @classmethod
    def from_measurements(cls, cfg: ModelConfig,
                          rows: Dict[Tuple[str, Tuple[int, int]], float]):
        t = cls(cfg)
        t._measured = dict(rows)
        return t

    def capacity(self, device: str, bucket: WorkloadBucket) -> float:
        key = (device, bucket.key)
        if key in self._measured:
            return self._measured[key]
        pm = PerfModel(self.cfg, DEVICES[device])
        return pm.capacity_rps(bucket, self.slo_ttft_s, self.slo_itl_s)

    def cost_per_request(self, device: str, bucket: WorkloadBucket
                         ) -> float:
        cap = self.capacity(device, bucket)
        if cap <= 0:
            return float("inf")
        return DEVICES[device].cost_per_hour / 3600.0 / cap
