"""SLO-driven heterogeneous GPU optimizer (paper §3.2.7, Figure 8).

Three components, matching the paper's architecture figure:

  * LoadMonitor  — turns gateway request logs into bucketed demand rates
  * GPUOptimizer — Mélange-inspired ILP: pick GPU counts per type that
                   minimize $/h subject to (a) every bucket's demand is
                   served, (b) only SLO-meeting (bucket, device)
                   assignments are allowed, (c) availability caps.
                   scipy MILP when available, greedy cover fallback.
  * External metric source — desired counts are exposed in the format
    the Pod Autoscaler consumes (one desired-replicas value per
    deployment), closing the paper's optimizer -> autoscaler loop.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.optimizer.profiles import (DEVICES, ProfileTable,
                                           WorkloadBucket)


@dataclass
class DemandBucket:
    bucket: WorkloadBucket
    rps: float


class LoadMonitor:
    """Aggregates gateway logs into representative workload buckets."""

    def __init__(self, in_edges: Sequence[int] = (200, 1000, 4000),
                 out_edges: Sequence[int] = (100, 500)):
        self.in_edges = list(in_edges)
        self.out_edges = list(out_edges)

    def _rep(self, idx: int, edges: List[int]) -> int:
        """Representative length for a bucket index."""
        lo = 0 if idx == 0 else edges[idx - 1]
        hi = edges[idx] if idx < len(edges) else lo * 2 or 8000
        return max((lo + hi) // 2, 16)

    def demand(self, request_log, window_s: float = 600.0,
               now: Optional[float] = None) -> List[DemandBucket]:
        if not request_log:
            return []
        now = request_log[-1][0] if now is None else now
        rows = [r for r in request_log if r[0] >= now - window_s]
        span = max(window_s, 1e-9)
        counts: Dict[Tuple[int, int], int] = {}
        for _, ilen, olen, _, _ in rows:
            bi = sum(ilen >= e for e in self.in_edges)
            bo = sum(olen >= e for e in self.out_edges)
            counts[(bi, bo)] = counts.get((bi, bo), 0) + 1
        out = []
        for (bi, bo), c in sorted(counts.items()):
            b = WorkloadBucket(self._rep(bi, self.in_edges),
                               self._rep(bo, self.out_edges))
            out.append(DemandBucket(b, c / span))
        return out


@dataclass
class Allocation:
    counts: Dict[str, int]
    cost_per_hour: float
    assignment: Dict[Tuple[Tuple[int, int], str], float]
    feasible: bool = True
    note: str = ""


class GPUOptimizer:
    def __init__(self, table: ProfileTable,
                 device_types: Sequence[str] = ("a10", "l20", "v100"),
                 availability: Optional[Dict[str, int]] = None,
                 headroom: float = 1.2):
        self.table = table
        self.device_types = list(device_types)
        self.availability = availability or {}
        self.headroom = headroom

    # ------------------------------------------------------------- solve
    def optimize(self, demand: List[DemandBucket]) -> Allocation:
        demand = [d for d in demand if d.rps > 0]
        if not demand:
            return Allocation({g: 0 for g in self.device_types}, 0.0, {})
        caps = {(i, gi): self.table.capacity(name, d.bucket)
                for i, d in enumerate(demand)
                for gi, name in enumerate(self.device_types)}
        try:
            return self._solve_milp(demand, caps)
        except Exception as e:  # scipy missing / infeasible numerical
            alloc = self._solve_greedy(demand, caps)
            alloc.note = f"greedy fallback ({type(e).__name__})"
            return alloc

    def _solve_milp(self, demand, caps) -> Allocation:
        import numpy as np
        from scipy.optimize import LinearConstraint, milp
        from scipy.optimize import Bounds

        nb, ng = len(demand), len(self.device_types)
        # variables: x[i,g] rps of bucket i on type g (continuous),
        #            n[g] device count (integer)
        nx = nb * ng

        def xi(i, g):
            return i * ng + g

        c = np.zeros(nx + ng)
        for g, name in enumerate(self.device_types):
            c[nx + g] = DEVICES[name].cost_per_hour
        A_rows, lbs, ubs = [], [], []
        # demand served: sum_g x[i,g] == demand_i * headroom
        for i, d in enumerate(demand):
            row = np.zeros(nx + ng)
            for g in range(ng):
                row[xi(i, g)] = 1.0
            A_rows.append(row)
            lbs.append(d.rps * self.headroom)
            ubs.append(d.rps * self.headroom)
        # capacity: sum_i x[i,g]/cap[i,g] <= n[g]
        for g in range(ng):
            row = np.zeros(nx + ng)
            for i in range(nb):
                cap = caps[(i, g)]
                row[xi(i, g)] = (1.0 / cap) if cap > 0 else 1e9
            row[nx + g] = -1.0
            A_rows.append(row)
            lbs.append(-np.inf)
            ubs.append(0.0)
        ub_x = np.full(nx + ng, np.inf)
        for g, name in enumerate(self.device_types):
            if name in self.availability:
                ub_x[nx + g] = self.availability[name]
        integrality = np.concatenate([np.zeros(nx), np.ones(ng)])
        res = milp(c=c,
                   constraints=LinearConstraint(np.array(A_rows),
                                                np.array(lbs),
                                                np.array(ubs)),
                   integrality=integrality,
                   bounds=Bounds(np.zeros(nx + ng), ub_x))
        if not res.success:
            raise RuntimeError(f"milp failed: {res.message}")
        counts = {name: int(round(res.x[nx + g]))
                  for g, name in enumerate(self.device_types)}
        assignment = {}
        for i, d in enumerate(demand):
            for g, name in enumerate(self.device_types):
                v = float(res.x[xi(i, g)])
                if v > 1e-9:
                    assignment[(d.bucket.key, name)] = v
        cost = sum(counts[n] * DEVICES[n].cost_per_hour for n in counts)
        return Allocation(counts, cost, assignment)

    def _solve_greedy(self, demand, caps) -> Allocation:
        """Cheapest-per-request device per bucket, then pack counts."""
        load_per_dev: Dict[str, float] = {g: 0.0 for g in self.device_types}
        assignment = {}
        for i, d in enumerate(demand):
            best, best_cpr = None, float("inf")
            for g, name in enumerate(self.device_types):
                cap = caps.get((i, g), 0)
                if cap <= 0:
                    continue
                cpr = DEVICES[name].cost_per_hour / cap
                if cpr < best_cpr:
                    best, best_cpr = name, cpr
            if best is None:
                return Allocation({g: 0 for g in self.device_types}, 0.0,
                                  {}, feasible=False,
                                  note=f"bucket {d.bucket.key} unservable")
            g = self.device_types.index(best)
            load_per_dev[best] += d.rps * self.headroom / caps[(i, g)]
            assignment[(d.bucket.key, best)] = d.rps
        counts = {}
        for name, load in load_per_dev.items():
            n = math.ceil(load)
            cap_limit = self.availability.get(name)
            if cap_limit is not None:
                n = min(n, cap_limit)
            counts[name] = n
        cost = sum(counts[n] * DEVICES[n].cost_per_hour for n in counts)
        return Allocation(counts, cost, assignment)

    # ----------------------------------------------- autoscaler interface
    def metric_source(self, demand: List[DemandBucket]) -> Dict[str, int]:
        """Desired replicas per device-typed deployment — the 'external
        MetricSource' the Pod Autoscaler reads (paper Figure 8)."""
        alloc = self.optimize(demand)
        return {f"deploy-{g}": n for g, n in alloc.counts.items()}


def homogeneous_cost(table: ProfileTable, demand: List[DemandBucket],
                     device: str, headroom: float = 1.2) -> Tuple[int, float]:
    """Baseline: serve everything on one device type."""
    load = 0.0
    for d in demand:
        cap = table.capacity(device, d.bucket)
        if cap <= 0:
            return 0, float("inf")
        load += d.rps * headroom / cap
    n = max(math.ceil(load), 1)
    return n, n * DEVICES[device].cost_per_hour
