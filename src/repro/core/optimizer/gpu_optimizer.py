"""SLO-driven heterogeneous GPU optimizer (paper §3.2.7, Figure 8).

Three components, matching the paper's architecture figure:

  * LoadMonitor  — turns gateway request logs into bucketed demand rates
  * GPUOptimizer — Mélange-inspired ILP: pick GPU counts per type that
                   minimize $/h subject to (a) every bucket's demand is
                   served, (b) only SLO-meeting (bucket, device)
                   assignments are allowed, (c) availability caps.
                   scipy MILP when available, greedy cover fallback.
  * External metric source — desired counts are exposed in the format
    the Pod Autoscaler consumes (one desired-replicas value per
    deployment), closing the paper's optimizer -> autoscaler loop.

Plus the role planner for P/D disaggregation: :func:`split_roles`
proposes the initial prefill:decode engine ratio from the roofline
profile and the SLO targets (prefill engine-seconds vs decode
engine-seconds per offered request); the RolePoolManager's
attainment-driven rebalancer then adapts that ratio live.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.optimizer.profiles import (DEVICES, PerfModel,
                                           ProfileTable, WorkloadBucket)


@dataclass
class DemandBucket:
    bucket: WorkloadBucket
    rps: float


class LoadMonitor:
    """Aggregates gateway logs into representative workload buckets."""

    def __init__(self, in_edges: Sequence[int] = (200, 1000, 4000),
                 out_edges: Sequence[int] = (100, 500)):
        self.in_edges = list(in_edges)
        self.out_edges = list(out_edges)

    def _rep(self, idx: int, edges: List[int]) -> int:
        """Representative length for a bucket index."""
        lo = 0 if idx == 0 else edges[idx - 1]
        hi = edges[idx] if idx < len(edges) else lo * 2 or 8000
        return max((lo + hi) // 2, 16)

    def demand(self, request_log, window_s: float = 600.0,
               now: Optional[float] = None) -> List[DemandBucket]:
        if not request_log:
            return []
        now = request_log[-1][0] if now is None else now
        rows = [r for r in request_log if r[0] >= now - window_s]
        span = max(window_s, 1e-9)
        counts: Dict[Tuple[int, int], int] = {}
        for _, ilen, olen, _, _ in rows:
            bi = sum(ilen >= e for e in self.in_edges)
            bo = sum(olen >= e for e in self.out_edges)
            counts[(bi, bo)] = counts.get((bi, bo), 0) + 1
        out = []
        for (bi, bo), c in sorted(counts.items()):
            b = WorkloadBucket(self._rep(bi, self.in_edges),
                               self._rep(bo, self.out_edges))
            out.append(DemandBucket(b, c / span))
        return out


@dataclass
class Allocation:
    counts: Dict[str, int]
    cost_per_hour: float
    assignment: Dict[Tuple[Tuple[int, int], str], float]
    feasible: bool = True
    note: str = ""


class GPUOptimizer:
    def __init__(self, table: ProfileTable,
                 device_types: Sequence[str] = ("a10", "l20", "v100"),
                 availability: Optional[Dict[str, int]] = None,
                 headroom: float = 1.2):
        self.table = table
        self.device_types = list(device_types)
        self.availability = availability or {}
        self.headroom = headroom

    # ------------------------------------------------------------- solve
    def optimize(self, demand: List[DemandBucket]) -> Allocation:
        demand = [d for d in demand if d.rps > 0]
        if not demand:
            return Allocation({g: 0 for g in self.device_types}, 0.0, {})
        caps = {(i, gi): self.table.capacity(name, d.bucket)
                for i, d in enumerate(demand)
                for gi, name in enumerate(self.device_types)}
        try:
            return self._solve_milp(demand, caps)
        except Exception as e:  # scipy missing / infeasible numerical
            alloc = self._solve_greedy(demand, caps)
            alloc.note = f"greedy fallback ({type(e).__name__})"
            return alloc

    def _solve_milp(self, demand, caps) -> Allocation:
        import numpy as np
        from scipy.optimize import LinearConstraint, milp
        from scipy.optimize import Bounds

        nb, ng = len(demand), len(self.device_types)
        # variables: x[i,g] rps of bucket i on type g (continuous),
        #            n[g] device count (integer)
        nx = nb * ng

        def xi(i, g):
            return i * ng + g

        c = np.zeros(nx + ng)
        for g, name in enumerate(self.device_types):
            c[nx + g] = DEVICES[name].cost_per_hour
        A_rows, lbs, ubs = [], [], []
        # demand served: sum_g x[i,g] == demand_i * headroom
        for i, d in enumerate(demand):
            row = np.zeros(nx + ng)
            for g in range(ng):
                row[xi(i, g)] = 1.0
            A_rows.append(row)
            lbs.append(d.rps * self.headroom)
            ubs.append(d.rps * self.headroom)
        # capacity: sum_i x[i,g]/cap[i,g] <= n[g]
        for g in range(ng):
            row = np.zeros(nx + ng)
            for i in range(nb):
                cap = caps[(i, g)]
                row[xi(i, g)] = (1.0 / cap) if cap > 0 else 1e9
            row[nx + g] = -1.0
            A_rows.append(row)
            lbs.append(-np.inf)
            ubs.append(0.0)
        ub_x = np.full(nx + ng, np.inf)
        for g, name in enumerate(self.device_types):
            if name in self.availability:
                ub_x[nx + g] = self.availability[name]
        integrality = np.concatenate([np.zeros(nx), np.ones(ng)])
        res = milp(c=c,
                   constraints=LinearConstraint(np.array(A_rows),
                                                np.array(lbs),
                                                np.array(ubs)),
                   integrality=integrality,
                   bounds=Bounds(np.zeros(nx + ng), ub_x))
        if not res.success:
            raise RuntimeError(f"milp failed: {res.message}")
        counts = {name: int(round(res.x[nx + g]))
                  for g, name in enumerate(self.device_types)}
        assignment = {}
        for i, d in enumerate(demand):
            for g, name in enumerate(self.device_types):
                v = float(res.x[xi(i, g)])
                if v > 1e-9:
                    assignment[(d.bucket.key, name)] = v
        cost = sum(counts[n] * DEVICES[n].cost_per_hour for n in counts)
        return Allocation(counts, cost, assignment)

    def _solve_greedy(self, demand, caps) -> Allocation:
        """Cheapest-per-request device per bucket, then pack counts."""
        load_per_dev: Dict[str, float] = {g: 0.0 for g in self.device_types}
        assignment = {}
        for i, d in enumerate(demand):
            best, best_cpr = None, float("inf")
            for g, name in enumerate(self.device_types):
                cap = caps.get((i, g), 0)
                if cap <= 0:
                    continue
                cpr = DEVICES[name].cost_per_hour / cap
                if cpr < best_cpr:
                    best, best_cpr = name, cpr
            if best is None:
                return Allocation({g: 0 for g in self.device_types}, 0.0,
                                  {}, feasible=False,
                                  note=f"bucket {d.bucket.key} unservable")
            g = self.device_types.index(best)
            load_per_dev[best] += d.rps * self.headroom / caps[(i, g)]
            assignment[(d.bucket.key, best)] = d.rps
        counts = {}
        for name, load in load_per_dev.items():
            n = math.ceil(load)
            cap_limit = self.availability.get(name)
            if cap_limit is not None:
                n = min(n, cap_limit)
            counts[name] = n
        cost = sum(counts[n] * DEVICES[n].cost_per_hour for n in counts)
        return Allocation(counts, cost, assignment)

    # ----------------------------------------------- autoscaler interface
    def metric_source(self, demand: List[DemandBucket]) -> Dict[str, int]:
        """Desired replicas per device-typed deployment — the 'external
        MetricSource' the Pod Autoscaler reads (paper Figure 8)."""
        alloc = self.optimize(demand)
        return {f"deploy-{g}": n for g, n in alloc.counts.items()}

    # ----------------------------------------------- P/D role planner
    def split_roles(self, demand: List[DemandBucket], device: str,
                    total_engines: Optional[int] = None,
                    slo_ttft_s: Optional[float] = None,
                    slo_itl_s: Optional[float] = None,
                    headroom: float = 1.2) -> "RoleSplit":
        """Propose the initial P:D engine ratio for a disaggregated
        fleet (see module-level :func:`split_roles`)."""
        return split_roles(self.table, demand, device,
                           total_engines=total_engines,
                           slo_ttft_s=slo_ttft_s, slo_itl_s=slo_itl_s,
                           headroom=headroom)


@dataclass
class RoleSplit:
    """A proposed prefill:decode split with its load accounting."""
    n_prefill: int
    n_decode: int
    prefill_load: float       # prefill engine-equivalents demanded
    decode_load: float        # decode engine-equivalents demanded
    note: str = ""

    @property
    def spec(self) -> str:
        """The '<n>P<m>D' role spec the launcher / sim parse."""
        return f"{self.n_prefill}P{self.n_decode}D"


def split_roles(table: ProfileTable, demand: List[DemandBucket],
                device: str, total_engines: Optional[int] = None,
                slo_ttft_s: Optional[float] = None,
                slo_itl_s: Optional[float] = None,
                headroom: float = 1.2, max_batch: int = 32) -> RoleSplit:
    """SLO-aware P:D planner over the roofline profile.

    Prefill demand is compute-bound engine-seconds per second
    (``rps * prefill_time(in_len)``); decode demand is bandwidth-bound
    engine-seconds (``rps * out_len * step_time(b)/b`` at the largest
    batch whose ITL still meets the SLO target — the target CAPS
    batching, which is exactly why decode pods multiply under tight
    ITL).  Unconstrained, each side gets ``ceil(load*headroom)``
    engines; with ``total_engines`` the ratio is apportioned at a
    minimum of one engine per role.  The returned split seeds the
    RolePoolManager; live attainment then corrects the model error.
    """
    pm = PerfModel(table.cfg, DEVICES[device])
    ttft = slo_ttft_s if slo_ttft_s is not None else table.slo_ttft_s
    itl = slo_itl_s if slo_itl_s is not None else table.slo_itl_s
    p_load = d_load = 0.0
    notes = []
    for d in demand:
        if d.rps <= 0:
            continue
        b = d.bucket
        ctx = b.in_len + b.out_len / 2.0
        pt = pm.prefill_time(b.in_len)
        if ttft is not None and pt > ttft:
            notes.append(f"bucket {b.key}: prefill {pt:.2f}s > "
                         f"TTFT target {ttft:.2f}s")
        p_load += d.rps * pt
        batch = 1
        while (batch * 2 <= max_batch
               and (itl is None
                    or pm.decode_step_time(batch * 2, int(ctx)) <= itl)):
            batch *= 2
        d_load += (d.rps * b.out_len
                   * pm.decode_step_time(batch, int(ctx)) / batch)
    p_load *= headroom
    d_load *= headroom
    if total_engines is not None:
        total = int(total_engines)
        if total < 2:
            raise ValueError("split_roles: a disaggregated fleet needs "
                             f"total_engines >= 2, got {total}")
        share = p_load / max(p_load + d_load, 1e-9)
        n_p = min(max(int(round(total * share)), 1), total - 1)
        n_d = total - n_p
    else:
        n_p = max(math.ceil(p_load), 1)
        n_d = max(math.ceil(d_load), 1)
    return RoleSplit(n_p, n_d, p_load, d_load, note="; ".join(notes))


def homogeneous_cost(table: ProfileTable, demand: List[DemandBucket],
                     device: str, headroom: float = 1.2) -> Tuple[int, float]:
    """Baseline: serve everything on one device type."""
    load = 0.0
    for d in demand:
        cap = table.capacity(device, d.bucket)
        if cap <= 0:
            return 0, float("inf")
        load += d.rps * headroom / cap
    n = max(math.ceil(load), 1)
    return n, n * DEVICES[device].cost_per_hour
