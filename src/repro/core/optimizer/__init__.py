from repro.core.optimizer.gpu_optimizer import (GPUOptimizer, LoadMonitor,  # noqa: F401
                                                RoleSplit, homogeneous_cost,
                                                split_roles)
from repro.core.optimizer.profiles import (DEVICES, PerfModel,  # noqa: F401
                                           ProfileTable, WorkloadBucket)
