"""KV placement tiers below device HBM (host DRAM) + the wire format.

The device ``PagePool`` (repro.engine.paged_model) and the cluster
``DistributedKVPool`` (repro.core.kvcache.pool) used to be the only two
homes a KV page could have, with nothing in between: a device eviction
dropped the bytes on the floor and a preemption recomputed from token 0.
This module adds the missing middle tier and the compressed wire format
the pool handoff path speaks:

``HostPagePool``
    A bounded host-DRAM page store, content-addressed by the SAME block
    hashes as the device prefix cache and the distributed pool, so the
    admission page walk can check device -> host -> distributed in
    order.  It is fed two ways: the :class:`~repro.engine.page_table.
    PageAllocator` eviction cascade (victims fall into this tier
    instead of vanishing) and swap-based preemption (a preempted
    request's pages — prompt AND generated — park here under per-
    request swap keys until resume).  Eviction is LRU; host evictions
    cascade into the :class:`SSDPagePool` below when one is wired via
    ``on_evict``.

``SSDPagePool``
    The third tier: a bounded SSD page store below host DRAM with
    *asynchronous write-behind* — ``put`` lands in a bounded in-RAM
    dirty buffer and returns immediately; a writer drains it to the
    backing store at SSD bandwidth (modelled ready-times on the
    simulator, a daemon thread writing pickle files on the real
    engine).  Idle-session prefixes and swapped-out requests survive
    host pressure here and resume without recompute.  Entries are
    never quantized (the swap path must stay byte-identical); when the
    dirty buffer is full, new puts are *dropped* (it is a cache — the
    page walk falls through to the distributed pool or recompute).

int8 wire compression (``compress_page`` / ``decompress_page``)
    The distributed-pool handoff path quantizes page payloads to int8
    with per-layer max-abs scales before they cross the wire and
    dequantizes on install.  Round-trip error is bounded by
    ``INT8_WIRE_MAX_REL_ERR`` times the per-layer max-abs value
    (pinned by tests/test_kv_tiers.py).  Host-tier entries are NOT
    compressed — the swap path must be byte-identical.
"""
from __future__ import annotations

import collections
import logging
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.kvtiers")

# pinned round-trip bound: |x - dequant(quant(x))| <= this * max|x| per
# scale group (symmetric int8 with round-to-nearest => half an LSB)
INT8_WIRE_MAX_REL_ERR = 0.5 / 127.0

# shared wire-format vocabulary: "int8" compresses; the "fp*" spellings
# all mean raw payloads ("fp" on the real engine — its pool arrays keep
# their native dtype — and "fp16" on the simulator, matching the
# roofline's kv_dtype_bytes).  Anything else is a typo that would
# otherwise silently disable compression.
WIRE_DTYPES = ("fp", "fp16", "fp32", "int8")


def validate_wire_dtype(name: str) -> str:
    if name not in WIRE_DTYPES:
        raise ValueError(f"unknown wire_dtype {name!r}; expected one of "
                         f"{WIRE_DTYPES}")
    return name


# --------------------------------------------------------------- wire format
@dataclass
class CompressedPage:
    """One page's (k, v) arrays quantized to int8 with per-layer scales.

    ``q_k``/``q_v`` keep the payload shape (L, page, Hkv, D); the scales
    are (L, 1, 1, 1) so dequantization is a single broadcast multiply.
    """
    q_k: np.ndarray
    q_v: np.ndarray
    k_scale: np.ndarray
    v_scale: np.ndarray

    @property
    def nbytes(self) -> int:
        return int(self.q_k.nbytes + self.q_v.nbytes
                   + self.k_scale.nbytes + self.v_scale.nbytes)


def _quant(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    x = np.asarray(x, np.float32)
    axes = tuple(range(1, x.ndim))
    scale = np.max(np.abs(x), axis=axes, keepdims=True) / 127.0
    scale = np.maximum(scale, 1e-12).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


def compress_page(k_page, v_page) -> CompressedPage:
    """Quantize one page payload for the pool wire (int8 + scales)."""
    q_k, k_scale = _quant(k_page)
    q_v, v_scale = _quant(v_page)
    return CompressedPage(q_k, q_v, k_scale, v_scale)


def decompress_page(cp: CompressedPage) -> Tuple[np.ndarray, np.ndarray]:
    return (cp.q_k.astype(np.float32) * cp.k_scale,
            cp.q_v.astype(np.float32) * cp.v_scale)


def payload_nbytes(payload: Any, default: int = 0) -> int:
    """Best-effort wire size of a page payload: CompressedPage and
    (k, v) array tuples know their bytes; opaque payloads (the
    simulator's ``True``) fall back to ``default``."""
    if isinstance(payload, CompressedPage):
        return payload.nbytes
    if isinstance(payload, tuple):
        n = sum(int(getattr(p, "nbytes", 0)) for p in payload)
        if n:
            return n
    return int(default)


# ---------------------------------------------------------------- host tier
@dataclass
class HostTierStats:
    puts: int = 0
    dup_puts: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_stored: int = 0
    bytes_offloaded: int = 0     # cumulative bytes written into the tier


class HostPagePool:
    """Bounded host-DRAM page tier between device HBM and the cluster
    pool.  Content-addressed (block hashes for cascade-evicted cache
    pages, ``swap/<rid>/<i>`` keys for swapped-out requests), LRU-
    evicting, payload-agnostic (real engines store raw (k, v) arrays —
    the swap path must be byte-identical, so host entries are never
    quantized; the simulator stores ``True`` and prices transfers with
    ``dram_bw``)."""

    def __init__(self, capacity_bytes: int = 4 << 30,
                 dram_bw: float = 50e9):
        self.capacity_bytes = int(capacity_bytes)
        self.dram_bw = dram_bw
        # key -> (payload, size_bytes); dict order == LRU order
        self._entries: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        self.stats = HostTierStats()
        # eviction cascade hook: on_evict(key, payload, size_bytes, now)
        # fires for every capacity eviction (NOT explicit discards) so
        # an SSDPagePool below can absorb the victim
        self.on_evict = None

    def __len__(self) -> int:
        return len(self._entries)

    def can_hold(self, nbytes: int) -> bool:
        """Whether ``nbytes`` could ever fit (evicting everything else
        if needed) — the swap-out feasibility check."""
        return nbytes <= self.capacity_bytes

    def contains(self, key: str) -> bool:
        return key in self._entries

    @property
    def utilization(self) -> float:
        return self.stats.bytes_stored / max(self.capacity_bytes, 1)

    def keys(self):
        return list(self._entries)

    # ------------------------------------------------------------ put/get
    def put(self, key: str, payload: Any, size_bytes: int,
            now: float = 0.0) -> bool:
        """Insert (or refresh) an entry; returns False when it cannot
        fit even after evicting every other entry."""
        size_bytes = int(size_bytes)
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.dup_puts += 1
            return True
        if size_bytes > self.capacity_bytes:
            return False
        while (self.stats.bytes_stored + size_bytes
               > self.capacity_bytes) and self._entries:
            vk, (vp, sz) = self._entries.popitem(last=False)
            self.stats.bytes_stored -= sz
            self.stats.evictions += 1
            if self.on_evict is not None:
                self.on_evict(vk, vp, sz, now)
        self._entries[key] = (payload, size_bytes)
        self.stats.bytes_stored += size_bytes
        self.stats.puts += 1
        self.stats.bytes_offloaded += size_bytes
        return True

    def get(self, key: str, now: float = 0.0) -> Optional[Any]:
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return ent[0]

    def discard(self, key: str) -> None:
        """Remove an entry without hit/miss accounting — swap-in holds
        the payloads it ``get()``-ed (so a cascade eviction racing the
        page allocation cannot invalidate them) and discards the keys
        only after the installs succeed."""
        ent = self._entries.pop(key, None)
        if ent is not None:
            self.stats.bytes_stored -= ent[1]


# ----------------------------------------------------------------- ssd tier
@dataclass
class SSDTierStats:
    puts: int = 0
    dup_puts: int = 0
    dropped_puts: int = 0        # write-behind buffer full => put dropped
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_stored: int = 0
    bytes_written: int = 0       # cumulative bytes drained to the SSD


class SSDPagePool:
    """Bounded SSD page tier below host DRAM with asynchronous
    write-behind.

    ``put`` appends to a bounded in-RAM dirty buffer and returns
    immediately; the writer drains it to the backing store at SSD
    bandwidth.  Two backings share the class:

    * **modelled** (``directory=None``, the simulator): each dirty
      entry carries a ready-time computed from a single serial writer
      draining at ``ssd_bw``; ``get``/``put`` lazily promote entries
      whose ready-time has passed into the durable LRU store.
    * **file-backed** (``directory=...``, the real engine): a daemon
      thread pickles payloads to files under ``directory`` — reads are
      byte-identical to what was written (payloads are never
      quantized), which the swap-resume pin in tests/test_sessions.py
      relies on.

    Entries still in the dirty buffer are readable (they live in RAM);
    when the buffer is full new puts are dropped and counted — it is a
    cache, so the page walk just falls through to the next tier.
    Drops are LOUD (like gateway sheds): accumulated and logged at most
    once per ``DROP_LOG_WINDOW_S`` so a saturated write-behind buffer
    shows up in bench output instead of silently degrading reuse.
    """

    DROP_LOG_WINDOW_S = 10.0      # at most one dropped-put log per window

    def __init__(self, capacity_bytes: int = 64 << 30,
                 ssd_bw: float = 3.0e9,
                 write_buffer_bytes: int = 256 << 20,
                 directory: Optional[str] = None):
        self.capacity_bytes = int(capacity_bytes)
        self.ssd_bw = ssd_bw
        self.write_buffer_bytes = int(write_buffer_bytes)
        # durable store: key -> (payload_or_path, size_bytes); LRU order
        self._entries: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        # write-behind buffer: key -> (payload, size_bytes, ready_time)
        self._dirty: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        self._dirty_bytes = 0
        self._writer_free_at = 0.0
        self.stats = SSDTierStats()
        # windowed dropped-put logging state (see _note_drop)
        self._drop_window = 0
        self._drop_t0 = 0.0
        self._drop_log_at = float("-inf")
        self._dir = directory
        self._lock = None
        self._queue = None
        if directory is not None:
            import os
            import queue
            import threading
            os.makedirs(directory, exist_ok=True)
            self._lock = threading.Lock()
            self._queue = queue.Queue()
            t = threading.Thread(target=self._file_writer, daemon=True)
            t.start()

    # --------------------------------------------------------- internals
    def _file_writer(self) -> None:
        """Daemon thread: drain the dirty queue to pickle files."""
        import os
        import pickle
        while True:
            key, payload, size_bytes = self._queue.get()
            path = os.path.join(
                self._dir, f"{abs(hash(key)) :x}-{self.stats.puts}.kv")
            with open(path, "wb") as f:
                pickle.dump(payload, f, protocol=4)
            with self._lock:
                if key in self._dirty:           # not discarded meanwhile
                    del self._dirty[key]
                    self._dirty_bytes -= size_bytes
                    self._store(key, path, size_bytes)
                    self.stats.bytes_written += size_bytes
                else:
                    os.remove(path)
            self._queue.task_done()

    def _store(self, key: str, payload: Any, size_bytes: int) -> None:
        """Insert into the durable LRU store, evicting to capacity."""
        while (self.stats.bytes_stored + size_bytes
               > self.capacity_bytes) and self._entries:
            vk, (vp, sz) = self._entries.popitem(last=False)
            self.stats.bytes_stored -= sz
            self.stats.evictions += 1
            self._unlink(vp)
            self._evicted(vk)
        self._entries[key] = (payload, size_bytes)
        self.stats.bytes_stored += size_bytes

    def _evicted(self, key: str) -> None:
        """Hook: a key left the pool (capacity eviction or discard).
        The host-shared subclass drops its writer-origin record here."""

    def _note_drop(self, now: float) -> None:
        """Dropped write-behind puts must be LOUD: accumulate and log
        at most once per DROP_LOG_WINDOW_S with the running total, so a
        full dirty buffer reads as a capacity problem, not light KV
        reuse."""
        if self._drop_window == 0:
            self._drop_t0 = now
        self._drop_window += 1
        if now >= self._drop_log_at:
            log.warning(
                "ssd write-behind dropped %d put(s) over the last %.1fs "
                "(total dropped=%d, dirty=%d/%d bytes) — raise "
                "write_buffer_bytes or SSD bandwidth if reuse matters",
                self._drop_window, max(now - self._drop_t0, 0.0),
                self.stats.dropped_puts, self._dirty_bytes,
                self.write_buffer_bytes)
            self._drop_window = 0
            self._drop_log_at = now + self.DROP_LOG_WINDOW_S

    def _unlink(self, payload: Any) -> None:
        if self._dir is not None and isinstance(payload, str):
            import os
            try:
                os.remove(payload)
            except OSError:
                pass

    def _flush(self, now: float) -> None:
        """Modelled backing: promote dirty entries whose write has
        completed by ``now`` into the durable store."""
        if self._dir is not None:
            return                     # the thread does real draining
        while self._dirty:
            key, (payload, sz, ready) = next(iter(self._dirty.items()))
            if ready > now:
                break
            del self._dirty[key]
            self._dirty_bytes -= sz
            self._store(key, payload, sz)
            self.stats.bytes_written += sz

    # ----------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._entries) + len(self._dirty)

    def can_hold(self, nbytes: int) -> bool:
        return nbytes <= self.capacity_bytes

    def contains(self, key: str) -> bool:
        if self._lock is not None:
            with self._lock:
                return key in self._dirty or key in self._entries
        return key in self._dirty or key in self._entries

    @property
    def utilization(self) -> float:
        return ((self.stats.bytes_stored + self._dirty_bytes)
                / max(self.capacity_bytes, 1))

    def keys(self):
        if self._lock is not None:
            with self._lock:
                return list(self._dirty) + list(self._entries)
        return list(self._dirty) + list(self._entries)

    # ------------------------------------------------------------ put/get
    def put(self, key: str, payload: Any, size_bytes: int,
            now: float = 0.0) -> bool:
        """Write-behind insert: lands in the dirty buffer and returns;
        the writer drains it at SSD bandwidth.  Returns False when the
        entry is too big or the dirty buffer is full (put dropped)."""
        size_bytes = int(size_bytes)
        if self._lock is not None:
            with self._lock:
                return self._put_locked(key, payload, size_bytes, now)
        return self._put_locked(key, payload, size_bytes, now)

    def _put_locked(self, key: str, payload: Any, size_bytes: int,
                    now: float) -> bool:
        self._flush(now)
        if key in self._dirty or key in self._entries:
            self.stats.dup_puts += 1
            if key in self._entries:
                self._entries.move_to_end(key)
            return True
        if size_bytes > self.capacity_bytes:
            return False
        if self._dirty_bytes + size_bytes > self.write_buffer_bytes:
            self.stats.dropped_puts += 1
            self._note_drop(now)
            return False
        if self._dir is None:
            ready = max(now, self._writer_free_at) \
                + size_bytes / self.ssd_bw
            self._writer_free_at = ready
            self._dirty[key] = (payload, size_bytes, ready)
        else:
            self._dirty[key] = (payload, size_bytes, 0.0)
            self._queue.put((key, payload, size_bytes))
        self._dirty_bytes += size_bytes
        self.stats.puts += 1
        return True

    def get(self, key: str, now: float = 0.0) -> Optional[Any]:
        """Fetch a payload: dirty-buffer entries are served from RAM,
        durable entries from the backing store (file-backed entries are
        unpickled — byte-identical to what was written)."""
        if self._lock is not None:
            with self._lock:
                return self._get_locked(key, now)
        return self._get_locked(key, now)

    def _get_locked(self, key: str, now: float) -> Optional[Any]:
        self._flush(now)
        ent = self._dirty.get(key)
        if ent is not None:
            self.stats.hits += 1
            return ent[0]
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        payload = ent[0]
        if self._dir is not None and isinstance(payload, str):
            import pickle
            with open(payload, "rb") as f:
                return pickle.load(f)
        return payload

    def discard(self, key: str) -> None:
        if self._lock is not None:
            with self._lock:
                self._discard_locked(key)
        else:
            self._discard_locked(key)

    def _discard_locked(self, key: str) -> None:
        ent = self._dirty.pop(key, None)
        if ent is not None:
            self._dirty_bytes -= ent[1]
            self._evicted(key)
            return
        ent = self._entries.pop(key, None)
        if ent is not None:
            self.stats.bytes_stored -= ent[1]
            self._unlink(ent[0])
            self._evicted(key)

    def drain(self, timeout: float = 10.0) -> None:
        """Block until every queued write has landed (file backing) or
        force-complete all modelled writes — tests and engine shutdown
        use this to make write-behind deterministic."""
        if self._queue is not None:
            self._queue.join()
        else:
            self._flush(float("inf"))


# ------------------------------------------------------- host-shared ssd tier
class SharedSSDPool(SSDPagePool):
    """Host-level shared SSD tier: every engine on the host attaches a
    :class:`SharedSSDView` to ONE content-addressed pool, so a prefix
    evicted by engine A is an SSD hit for engine B instead of a
    duplicate file.  Block hashes are engine-independent (token content
    + page size + adapter), which is what makes cross-engine sharing
    sound; swap keys (``swap/<rid>/<i>``) carry the request id and stay
    effectively engine-private.

    One write-behind drain path is shared (the single daemon thread /
    modelled serial writer of the base class); per-engine accounting
    lives on the views.  The pool remembers each key's first writer so
    a hit can be classified same-engine vs cross-engine, and counts the
    puts (and bytes) that deduplicated against another engine's copy —
    the headline dedupe metric."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._origin: Dict[str, str] = {}     # key -> first-writer engine
        self._views: Dict[str, "SharedSSDView"] = {}
        self.dedup_puts = 0       # puts absorbed by another engine's copy
        self.dedup_bytes = 0      # bytes those puts would have written

    def view(self, engine_id: str) -> "SharedSSDView":
        """The engine's handle on the shared pool (one per engine,
        cached — accounting accumulates across reattaches)."""
        v = self._views.get(engine_id)
        if v is None:
            v = self._views[engine_id] = SharedSSDView(self, engine_id)
        return v

    @property
    def dedupe_ratio(self) -> float:
        """Fraction of distinct-content put attempts that were absorbed
        by a copy some OTHER engine already wrote (0.0 when nothing was
        shared)."""
        return self.dedup_puts / max(self.stats.puts + self.dedup_puts, 1)

    def _evicted(self, key: str) -> None:
        self._origin.pop(key, None)

    # per-view entry points: classification must happen under the same
    # lock as the put/get so concurrent engine threads stay consistent
    def put_from(self, view: "SharedSSDView", key: str, payload: Any,
                 size_bytes: int, now: float = 0.0) -> bool:
        size_bytes = int(size_bytes)
        if self._lock is not None:
            with self._lock:
                return self._put_from_locked(view, key, payload,
                                             size_bytes, now)
        return self._put_from_locked(view, key, payload, size_bytes, now)

    def _put_from_locked(self, view, key, payload, size_bytes, now):
        puts0 = self.stats.puts
        dups0 = self.stats.dup_puts
        drops0 = self.stats.dropped_puts
        ok = self._put_locked(key, payload, size_bytes, now)
        if self.stats.puts > puts0:               # fresh write
            self._origin[key] = view.engine_id
            view.stats.puts += 1
        elif self.stats.dup_puts > dups0:         # already resident
            view.stats.dup_puts += 1
            if self._origin.get(key, view.engine_id) != view.engine_id:
                self.dedup_puts += 1
                self.dedup_bytes += size_bytes
        elif self.stats.dropped_puts > drops0:    # dirty buffer full
            view.stats.dropped_puts += 1
        return ok

    def get_from(self, view: "SharedSSDView", key: str,
                 now: float = 0.0) -> Optional[Any]:
        if self._lock is not None:
            with self._lock:
                return self._get_from_locked(view, key, now)
        return self._get_from_locked(view, key, now)

    def _get_from_locked(self, view, key, now):
        payload = self._get_locked(key, now)
        if payload is None:
            view.stats.misses += 1
            view.last_get_cross = False
            return None
        view.stats.hits += 1
        cross = self._origin.get(key, view.engine_id) != view.engine_id
        view.last_get_cross = cross
        if cross:
            view.cross_hits += 1
        return payload


class SharedSSDView:
    """One engine's facade over a :class:`SharedSSDPool` — the same
    interface the scheduler already speaks to a private
    :class:`SSDPagePool` (put/get/contains/discard/keys/drain/stats/
    ssd_bw/capacity_bytes/can_hold), plus cross-engine hit
    classification:

    * ``stats`` counts THIS engine's traffic (its puts may dedupe
      against a sibling's copy; its hits may land on pages a sibling
      wrote).  ``bytes_stored``/``bytes_written`` stay pool-global —
      read them off ``pool.stats``.
    * ``cross_hits`` counts hits on pages another engine wrote, and
      ``last_get_cross`` flags whether the most recent successful get
      was one — the scheduler turns that into ``ssd_cross_hit_tokens``.
    """

    def __init__(self, pool: SharedSSDPool, engine_id: str):
        self.pool = pool
        self.engine_id = engine_id
        self.stats = SSDTierStats()
        self.cross_hits = 0
        self.last_get_cross = False

    # ----------------------------------------------- pool-global queries
    @property
    def ssd_bw(self) -> float:
        return self.pool.ssd_bw

    @property
    def capacity_bytes(self) -> int:
        return self.pool.capacity_bytes

    @property
    def utilization(self) -> float:
        return self.pool.utilization

    def can_hold(self, nbytes: int) -> bool:
        return self.pool.can_hold(nbytes)

    def contains(self, key: str) -> bool:
        return self.pool.contains(key)

    def keys(self):
        return self.pool.keys()

    def __len__(self) -> int:
        return len(self.pool)

    # -------------------------------------------------- per-engine traffic
    def put(self, key: str, payload: Any, size_bytes: int,
            now: float = 0.0) -> bool:
        return self.pool.put_from(self, key, payload, size_bytes, now)

    def get(self, key: str, now: float = 0.0) -> Optional[Any]:
        return self.pool.get_from(self, key, now)

    def discard(self, key: str) -> None:
        self.pool.discard(key)

    def drain(self, timeout: float = 10.0) -> None:
        self.pool.drain(timeout)
