"""KV placement tiers below device HBM (host DRAM) + the wire format.

The device ``PagePool`` (repro.engine.paged_model) and the cluster
``DistributedKVPool`` (repro.core.kvcache.pool) used to be the only two
homes a KV page could have, with nothing in between: a device eviction
dropped the bytes on the floor and a preemption recomputed from token 0.
This module adds the missing middle tier and the compressed wire format
the pool handoff path speaks:

``HostPagePool``
    A bounded host-DRAM page store, content-addressed by the SAME block
    hashes as the device prefix cache and the distributed pool, so the
    admission page walk can check device -> host -> distributed in
    order.  It is fed two ways: the :class:`~repro.engine.page_table.
    PageAllocator` eviction cascade (victims fall into this tier
    instead of vanishing) and swap-based preemption (a preempted
    request's pages — prompt AND generated — park here under per-
    request swap keys until resume).  Eviction is LRU; an SSD third
    tier below it is a ROADMAP follow-up.

int8 wire compression (``compress_page`` / ``decompress_page``)
    The distributed-pool handoff path quantizes page payloads to int8
    with per-layer max-abs scales before they cross the wire and
    dequantizes on install.  Round-trip error is bounded by
    ``INT8_WIRE_MAX_REL_ERR`` times the per-layer max-abs value
    (pinned by tests/test_kv_tiers.py).  Host-tier entries are NOT
    compressed — the swap path must be byte-identical.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import numpy as np

# pinned round-trip bound: |x - dequant(quant(x))| <= this * max|x| per
# scale group (symmetric int8 with round-to-nearest => half an LSB)
INT8_WIRE_MAX_REL_ERR = 0.5 / 127.0

# shared wire-format vocabulary: "int8" compresses; the "fp*" spellings
# all mean raw payloads ("fp" on the real engine — its pool arrays keep
# their native dtype — and "fp16" on the simulator, matching the
# roofline's kv_dtype_bytes).  Anything else is a typo that would
# otherwise silently disable compression.
WIRE_DTYPES = ("fp", "fp16", "fp32", "int8")


def validate_wire_dtype(name: str) -> str:
    if name not in WIRE_DTYPES:
        raise ValueError(f"unknown wire_dtype {name!r}; expected one of "
                         f"{WIRE_DTYPES}")
    return name


# --------------------------------------------------------------- wire format
@dataclass
class CompressedPage:
    """One page's (k, v) arrays quantized to int8 with per-layer scales.

    ``q_k``/``q_v`` keep the payload shape (L, page, Hkv, D); the scales
    are (L, 1, 1, 1) so dequantization is a single broadcast multiply.
    """
    q_k: np.ndarray
    q_v: np.ndarray
    k_scale: np.ndarray
    v_scale: np.ndarray

    @property
    def nbytes(self) -> int:
        return int(self.q_k.nbytes + self.q_v.nbytes
                   + self.k_scale.nbytes + self.v_scale.nbytes)


def _quant(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    x = np.asarray(x, np.float32)
    axes = tuple(range(1, x.ndim))
    scale = np.max(np.abs(x), axis=axes, keepdims=True) / 127.0
    scale = np.maximum(scale, 1e-12).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


def compress_page(k_page, v_page) -> CompressedPage:
    """Quantize one page payload for the pool wire (int8 + scales)."""
    q_k, k_scale = _quant(k_page)
    q_v, v_scale = _quant(v_page)
    return CompressedPage(q_k, q_v, k_scale, v_scale)


def decompress_page(cp: CompressedPage) -> Tuple[np.ndarray, np.ndarray]:
    return (cp.q_k.astype(np.float32) * cp.k_scale,
            cp.q_v.astype(np.float32) * cp.v_scale)


def payload_nbytes(payload: Any, default: int = 0) -> int:
    """Best-effort wire size of a page payload: CompressedPage and
    (k, v) array tuples know their bytes; opaque payloads (the
    simulator's ``True``) fall back to ``default``."""
    if isinstance(payload, CompressedPage):
        return payload.nbytes
    if isinstance(payload, tuple):
        n = sum(int(getattr(p, "nbytes", 0)) for p in payload)
        if n:
            return n
    return int(default)


# ---------------------------------------------------------------- host tier
@dataclass
class HostTierStats:
    puts: int = 0
    dup_puts: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_stored: int = 0
    bytes_offloaded: int = 0     # cumulative bytes written into the tier


class HostPagePool:
    """Bounded host-DRAM page tier between device HBM and the cluster
    pool.  Content-addressed (block hashes for cascade-evicted cache
    pages, ``swap/<rid>/<i>`` keys for swapped-out requests), LRU-
    evicting, payload-agnostic (real engines store raw (k, v) arrays —
    the swap path must be byte-identical, so host entries are never
    quantized; the simulator stores ``True`` and prices transfers with
    ``dram_bw``)."""

    def __init__(self, capacity_bytes: int = 4 << 30,
                 dram_bw: float = 50e9):
        self.capacity_bytes = int(capacity_bytes)
        self.dram_bw = dram_bw
        # key -> (payload, size_bytes); dict order == LRU order
        self._entries: "collections.OrderedDict[str, tuple]" = \
            collections.OrderedDict()
        self.stats = HostTierStats()

    def __len__(self) -> int:
        return len(self._entries)

    def can_hold(self, nbytes: int) -> bool:
        """Whether ``nbytes`` could ever fit (evicting everything else
        if needed) — the swap-out feasibility check."""
        return nbytes <= self.capacity_bytes

    def contains(self, key: str) -> bool:
        return key in self._entries

    @property
    def utilization(self) -> float:
        return self.stats.bytes_stored / max(self.capacity_bytes, 1)

    def keys(self):
        return list(self._entries)

    # ------------------------------------------------------------ put/get
    def put(self, key: str, payload: Any, size_bytes: int,
            now: float = 0.0) -> bool:
        """Insert (or refresh) an entry; returns False when it cannot
        fit even after evicting every other entry."""
        size_bytes = int(size_bytes)
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.dup_puts += 1
            return True
        if size_bytes > self.capacity_bytes:
            return False
        while (self.stats.bytes_stored + size_bytes
               > self.capacity_bytes) and self._entries:
            _, (_, sz) = self._entries.popitem(last=False)
            self.stats.bytes_stored -= sz
            self.stats.evictions += 1
        self._entries[key] = (payload, size_bytes)
        self.stats.bytes_stored += size_bytes
        self.stats.puts += 1
        self.stats.bytes_offloaded += size_bytes
        return True

    def get(self, key: str, now: float = 0.0) -> Optional[Any]:
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return ent[0]

    def discard(self, key: str) -> None:
        """Remove an entry without hit/miss accounting — swap-in holds
        the payloads it ``get()``-ed (so a cascade eviction racing the
        page allocation cannot invalidate them) and discards the keys
        only after the installs succeed."""
        ent = self._entries.pop(key, None)
        if ent is not None:
            self.stats.bytes_stored -= ent[1]
