from repro.core.kvcache.eviction import LRU, LRUK, S3FIFO, make_policy  # noqa: F401
from repro.core.kvcache.pool import DistributedKVPool, KVBlock  # noqa: F401
