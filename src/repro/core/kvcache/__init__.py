from repro.core.kvcache.eviction import LRU, LRUK, S3FIFO, make_policy  # noqa: F401
from repro.core.kvcache.pool import DistributedKVPool, KVBlock  # noqa: F401
from repro.core.kvcache.tiers import (CompressedPage, HostPagePool,  # noqa: F401
                                      INT8_WIRE_MAX_REL_ERR,
                                      compress_page, decompress_page,
                                      payload_nbytes)
