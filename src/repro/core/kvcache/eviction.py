"""Eviction policies for the distributed KV cache pool.

The paper calls for a *scan-resistant* policy that "selectively persists
hot KV tensors".  One-shot prompt scans (a big Bird-SQL schema seen
once) must not flush the genuinely-hot multi-turn prefixes.  We provide:

  * LRU            — baseline (what a naive pool would do)
  * S3FIFO         — scan-resistant: small probationary FIFO absorbs
                     one-hit-wonder blocks; only re-referenced blocks
                     graduate to the main FIFO (Yang et al., SOSP'23 —
                     the family AIBrix's eviction is drawn from)
  * LRU-K (K=2)    — classic scan-resistant alternative for ablations

All policies share one interface: on_insert / on_access / evict -> key.
"""
from __future__ import annotations

import collections
from typing import Dict, Hashable, Optional


class EvictionPolicy:
    name = "base"

    def on_insert(self, key: Hashable, size: int = 1) -> None:
        raise NotImplementedError

    def on_access(self, key: Hashable) -> None:
        raise NotImplementedError

    def on_remove(self, key: Hashable) -> None:
        raise NotImplementedError

    def evict(self) -> Optional[Hashable]:
        """Choose and forget a victim key (None if empty)."""
        raise NotImplementedError

    def __contains__(self, key) -> bool:
        raise NotImplementedError


class LRU(EvictionPolicy):
    name = "lru"

    def __init__(self):
        self._od: "collections.OrderedDict[Hashable, None]" = \
            collections.OrderedDict()

    def on_insert(self, key, size: int = 1):
        self._od[key] = None
        self._od.move_to_end(key)

    def on_access(self, key):
        if key in self._od:
            self._od.move_to_end(key)

    def on_remove(self, key):
        self._od.pop(key, None)

    def evict(self):
        if not self._od:
            return None
        key, _ = self._od.popitem(last=False)
        return key

    def __contains__(self, key):
        return key in self._od


class S3FIFO(EvictionPolicy):
    """Small (probationary) FIFO + main FIFO + ghost queue.

    * new keys -> small FIFO (default 10% of capacity budget)
    * eviction from small: freq>0 -> promote to main, else -> ghost
    * re-insert of a ghost key -> straight to main (it proved hotness)
    * eviction from main: freq>0 -> reinsert with freq-1 (lazy CLOCK),
      else evict for real.
    """
    name = "s3fifo"

    def __init__(self, capacity: int = 1024, small_ratio: float = 0.1,
                 ghost_ratio: float = 0.9):
        self.capacity = max(capacity, 2)
        self.small_cap = max(1, int(self.capacity * small_ratio))
        self.ghost_cap = max(1, int(self.capacity * ghost_ratio))
        self.small: "collections.deque[Hashable]" = collections.deque()
        self.main: "collections.deque[Hashable]" = collections.deque()
        self.ghost: "collections.OrderedDict[Hashable, None]" = \
            collections.OrderedDict()
        self.freq: Dict[Hashable, int] = {}
        self.where: Dict[Hashable, str] = {}

    def on_insert(self, key, size: int = 1):
        if key in self.where:
            self.on_access(key)
            return
        if key in self.ghost:                    # proven hot: main
            del self.ghost[key]
            self.main.append(key)
            self.where[key] = "main"
        else:
            self.small.append(key)
            self.where[key] = "small"
        self.freq[key] = 0

    def on_access(self, key):
        if key in self.freq:
            self.freq[key] = min(self.freq[key] + 1, 3)

    def on_remove(self, key):
        loc = self.where.pop(key, None)
        if loc == "small":
            try:
                self.small.remove(key)
            except ValueError:
                pass
        elif loc == "main":
            try:
                self.main.remove(key)
            except ValueError:
                pass
        self.freq.pop(key, None)

    def _ghost_insert(self, key):
        self.ghost[key] = None
        while len(self.ghost) > self.ghost_cap:
            self.ghost.popitem(last=False)

    def evict(self):
        # prefer draining an over-full small queue (scan absorption).
        # bound: each key gets at most freq-cap+1 = 4 second chances
        for _ in range(4 * (len(self.small) + len(self.main)) + 4):
            if self.small and (len(self.small) >= self.small_cap
                               or not self.main):
                key = self.small.popleft()
                if self.freq.get(key, 0) > 0:    # survived: promote
                    self.main.append(key)
                    self.where[key] = "main"
                    self.freq[key] = 0
                    continue
                self.where.pop(key, None)
                self.freq.pop(key, None)
                self._ghost_insert(key)
                return key
            if self.main:
                key = self.main.popleft()
                if self.freq.get(key, 0) > 0:    # lazy CLOCK second chance
                    self.freq[key] -= 1
                    self.main.append(key)
                    continue
                self.where.pop(key, None)
                self.freq.pop(key, None)
                return key
            if self.small:                        # main empty: drain small
                key = self.small.popleft()
                self.where.pop(key, None)
                self.freq.pop(key, None)
                self._ghost_insert(key)
                return key
        return None

    def __contains__(self, key):
        return key in self.where


class LRUK(EvictionPolicy):
    """LRU-K (K=2): evict the key with the oldest K-th-last access."""
    name = "lru2"

    def __init__(self, k: int = 2):
        self.k = k
        self.hist: Dict[Hashable, collections.deque] = {}
        self._tick = 0

    def _now(self) -> int:
        self._tick += 1
        return self._tick

    def on_insert(self, key, size: int = 1):
        self.hist[key] = collections.deque([self._now()], maxlen=self.k)

    def on_access(self, key):
        if key in self.hist:
            self.hist[key].append(self._now())

    def on_remove(self, key):
        self.hist.pop(key, None)

    def evict(self):
        if not self.hist:
            return None
        # backward-K distance: keys with < K accesses are "infinitely" old
        def kth(key):
            h = self.hist[key]
            return h[0] if len(h) >= self.k else -1_000_000_000 + h[-1]
        victim = min(self.hist, key=kth)
        del self.hist[victim]
        return victim

    def __contains__(self, key):
        return key in self.hist


POLICIES = {"lru": LRU, "s3fifo": S3FIFO, "lru2": LRUK}


def make_policy(name: str, capacity: int) -> EvictionPolicy:
    if name == "s3fifo":
        return S3FIFO(capacity)
    if name == "lru2":
        return LRUK()
    return LRU()
