"""Distributed KV cache pool (paper §3.2.5, Figure 5).

Cluster-scope, content-addressed store of KV blocks, shared by every
engine.  Reproduces the paper's four stated mechanisms:

  1. **Scan-resistant eviction** — pluggable policy, S3-FIFO by default
     (one-shot prompt scans don't flush hot multi-turn prefixes).
  2. **Reduced redundant transfers** — blocks are fetched at most once
     per miss; publishes of a hash the pool already holds are dropped
     at the metadata layer before any payload moves.
  3. **Asynchronous metadata updates** — publishes enqueue a metadata
     record and return immediately; a background flush (``tick``) makes
     them visible, so the engine's token path never waits on the pool
     index (visibility_lag models the paper's async update window).
  4. **Shared-memory colocation** — fetches by an engine colocated with
     the block's home node are zero-copy (cost model: dram_bw vs
     network_bw), mirroring the cache-engine colocation fast path.

Payloads are optional: real engines store (k_page, v_page) arrays; the
cluster simulator stores None and uses the cost model only.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.kvcache.eviction import make_policy


@dataclass
class KVBlock:
    block_hash: str
    payload: Any                       # (k_page, v_page) or None (sim)
    size_bytes: int
    home_node: str                     # node that produced it
    created_at: float = 0.0
    hits: int = 0


class KVPoolError(RuntimeError):
    """Pool unreachable — network partition or cache-node loss.  Raised
    by ``fetch``/``publish`` while a partition window is active; callers
    (the scheduler's pool walk) must degrade to recompute, never crash."""


@dataclass
class PoolStats:
    puts: int = 0
    dup_puts_dropped: int = 0
    hits_local: int = 0                # shared-memory (colocated) hits
    hits_remote: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_stored: int = 0
    bytes_transferred: int = 0
    pending_metadata: int = 0
    fetch_failures: int = 0            # fetches rejected by a partition
    publish_failures: int = 0          # publishes rejected by a partition


class DistributedKVPool:
    """One logical pool; engines attach with a node id for colocation."""

    def __init__(self, capacity_bytes: int = 8 << 30,
                 block_bytes: int = 1 << 20,
                 policy: str = "s3fifo",
                 metadata_lag: float = 0.002,
                 network_bw: float = 12.5e9,      # 100 Gb/s fabric
                 dram_bw: float = 50e9,
                 clock: Callable[[], float] = None):
        self.capacity_bytes = capacity_bytes
        self.block_bytes = block_bytes
        self.policy = make_policy(policy, max(capacity_bytes // block_bytes,
                                              2))
        self.metadata_lag = metadata_lag
        self.network_bw = network_bw
        self.dram_bw = dram_bw
        self.clock = clock or (lambda: 0.0)
        self.blocks: Dict[str, KVBlock] = {}
        self.stats = PoolStats()
        # async metadata queue: (visible_at, hash, block), plus an O(1)
        # membership set (contains()/publish dedup sit on the engines'
        # per-block prefill-completion hot path)
        self._pending: "collections.deque[Tuple[float, str, KVBlock]]" = \
            collections.deque()
        self._pending_hashes: set = set()
        # engine node map (engine_id -> node id) for colocation checks
        self._engine_node: Dict[str, str] = {}
        # chaos: while now < _partition_until, fetch/publish raise
        self._partition_until: float = float("-inf")

    # ---------------------------------------------------------- partition
    def partition(self, now: Optional[float] = None,
                  duration: float = 1.0) -> None:
        """Sever the pool for ``duration`` seconds (chaos injection)."""
        now = self.clock() if now is None else now
        self._partition_until = max(self._partition_until, now + duration)

    def heal(self) -> None:
        self._partition_until = float("-inf")

    def partitioned(self, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        return now < self._partition_until

    # ------------------------------------------------------------ attach
    def attach_engine(self, engine_id: str, node: str) -> None:
        self._engine_node[engine_id] = node

    # ------------------------------------------------------------ publish
    def publish(self, block_hash: str, payload: Any, engine_id: str,
                now: Optional[float] = None, size_bytes: int = 0) -> bool:
        """Async publish; returns False when dropped as duplicate."""
        now = self.clock() if now is None else now
        if self.partitioned(now):
            self.stats.publish_failures += 1
            raise KVPoolError("kv pool partitioned: publish rejected")
        if self.contains(block_hash):
            self.stats.dup_puts_dropped += 1
            return False
        blk = KVBlock(block_hash, payload,
                      size_bytes or self.block_bytes,
                      home_node=self._engine_node.get(engine_id, engine_id),
                      created_at=now)
        self._pending.append((now + self.metadata_lag, block_hash, blk))
        self._pending_hashes.add(block_hash)
        self.stats.puts += 1
        self.stats.pending_metadata = len(self._pending)
        return True

    def tick(self, now: Optional[float] = None) -> int:
        """Flush metadata records that became visible.  Returns #flushed."""
        now = self.clock() if now is None else now
        n = 0
        while self._pending and self._pending[0][0] <= now:
            _, h, blk = self._pending.popleft()
            self._pending_hashes.discard(h)
            if h in self.blocks:
                self.stats.dup_puts_dropped += 1
                continue
            self._insert(blk)
            n += 1
        self.stats.pending_metadata = len(self._pending)
        return n

    def flush_hashes(self, hashes, now: Optional[float] = None) -> int:
        """Synchronously make SPECIFIC pending records visible — a
        handoff barrier for disaggregated prefill engines, which must
        not hand a request off before its published blocks are
        fetchable.  Other engines' pending records keep their
        configured metadata lag.  Returns #flushed."""
        wanted = set(hashes) & self._pending_hashes
        if not wanted:
            return 0
        n = 0
        keep: "collections.deque" = collections.deque()
        while self._pending:
            vis, h, blk = self._pending.popleft()
            if h not in wanted:
                keep.append((vis, h, blk))
                continue
            self._pending_hashes.discard(h)
            if h in self.blocks:
                self.stats.dup_puts_dropped += 1
            else:
                self._insert(blk)
                n += 1
        self._pending = keep
        self.stats.pending_metadata = len(self._pending)
        return n

    def _insert(self, blk: KVBlock) -> None:
        while (self.stats.bytes_stored + blk.size_bytes
               > self.capacity_bytes):
            victim = self.policy.evict()
            if victim is None:
                return                      # cannot fit
            vb = self.blocks.pop(victim, None)
            if vb is not None:
                self.stats.bytes_stored -= vb.size_bytes
                self.stats.evictions += 1
        self.blocks[blk.block_hash] = blk
        self.policy.on_insert(blk.block_hash)
        self.stats.bytes_stored += blk.size_bytes

    # ------------------------------------------------------------ fetch
    def contains(self, block_hash: str) -> bool:
        """Known to the pool: visible OR queued in the async metadata
        path (fetchable after the lag; a publish would be dropped as a
        duplicate) — so engines can skip materializing payloads for
        blocks published moments ago."""
        return block_hash in self.blocks or block_hash in self._pending_hashes

    def fetch(self, block_hash: str, engine_id: str,
              now: Optional[float] = None) -> Optional[Any]:
        """Payload or None.  Updates hotness + transfer accounting."""
        if self.partitioned(now):
            self.stats.fetch_failures += 1
            raise KVPoolError("kv pool partitioned: fetch rejected")
        self.tick(now)
        blk = self.blocks.get(block_hash)
        if blk is None:
            self.stats.misses += 1
            return None
        blk.hits += 1
        self.policy.on_access(block_hash)
        node = self._engine_node.get(engine_id, engine_id)
        if node == blk.home_node:
            self.stats.hits_local += 1
        else:
            self.stats.hits_remote += 1
            self.stats.bytes_transferred += blk.size_bytes
        return blk.payload if blk.payload is not None else True

    def size_of(self, block_hash: str) -> int:
        """Stored wire size of a visible block (0 when unknown) — what
        a fetch of it actually moves (int8-compressed payloads are
        smaller than the raw page)."""
        blk = self.blocks.get(block_hash)
        return blk.size_bytes if blk is not None else 0

    def fetch_cost_s(self, block_hash: str, engine_id: str) -> float:
        """Transfer-time model for the simulator (s)."""
        blk = self.blocks.get(block_hash)
        if blk is None:
            return 0.0
        node = self._engine_node.get(engine_id, engine_id)
        bw = self.dram_bw if node == blk.home_node else self.network_bw
        return blk.size_bytes / bw

    # ------------------------------------------------------------ misc
    def match_prefix(self, hashes: List[str]) -> int:
        """Longest visible prefix run (router/scheduler scoring)."""
        n = 0
        for h in hashes:
            if h not in self.blocks:
                break
            n += 1
        return n

    @property
    def utilization(self) -> float:
        return self.stats.bytes_stored / max(self.capacity_bytes, 1)
