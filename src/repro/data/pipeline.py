"""Data pipeline: byte-level tokenizer + synthetic LM corpora + batching.

For the end-to-end training example we synthesize a corpus with real
(learnable) statistical structure — a char-level Markov source over a
fixed transition table — so the ~100M-model driver shows an actual loss
curve rather than noise-floor flatlining on uniform random tokens.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp


class ByteTokenizer:
    """Trivial byte-level tokenizer (vocab 256 + specials)."""
    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str, add_bos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [self.BOS] + ids
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        return bytes(int(i) for i in ids if int(i) < 256).decode(
            "utf-8", errors="replace")


def markov_corpus(num_tokens: int, vocab: int, order_state: int = 64,
                  seed: int = 0, temperature: float = 1.0) -> np.ndarray:
    """Synthetic corpus from a random sparse Markov chain over ``vocab``."""
    rng = np.random.default_rng(seed)
    states = order_state
    # sparse transition: each state strongly prefers ~8 tokens
    prefs = rng.integers(0, vocab, size=(states, 8))
    logits = rng.normal(0, 1, size=(states, 8)) / temperature
    probs = np.exp(logits)
    probs /= probs.sum(1, keepdims=True)
    out = np.empty(num_tokens, np.int32)
    s = 0
    choice_buf = rng.random(num_tokens)
    for i in range(num_tokens):
        c = np.searchsorted(np.cumsum(probs[s]), choice_buf[i])
        tok = prefs[s, min(c, 7)]
        out[i] = tok
        s = int(tok) % states
    return out


class TokenPipeline:
    """Chunked LM batches from a flat token stream, with shift labels."""

    def __init__(self, tokens: np.ndarray, batch: int, seq: int,
                 num_codebooks: int = 0, seed: int = 0):
        self.tokens = tokens
        self.batch, self.seq = batch, seq
        self.num_codebooks = num_codebooks
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        n = len(self.tokens) - self.seq - 1
        while True:
            starts = self.rng.integers(0, n, size=self.batch)
            toks = np.stack([self.tokens[s:s + self.seq] for s in starts])
            labels = np.stack([self.tokens[s + 1:s + self.seq + 1]
                               for s in starts])
            if self.num_codebooks:
                k = self.num_codebooks
                toks = np.stack([np.roll(toks, i, -1) for i in range(k)], -1)
                labels = np.stack([np.roll(labels, i, -1)
                                   for i in range(k)], -1)
            yield {"tokens": jnp.asarray(toks),
                   "labels": jnp.asarray(labels),
                   "weights": jnp.ones((self.batch, self.seq), jnp.float32)}


def synthetic_lm_batches(vocab: int, batch: int, seq: int,
                         num_codebooks: int = 0, seed: int = 0,
                         corpus_tokens: int = 200_000):
    """Infinite iterator of learnable synthetic LM batches."""
    corpus = markov_corpus(corpus_tokens, vocab, seed=seed)
    return iter(TokenPipeline(corpus, batch, seq,
                              num_codebooks=num_codebooks, seed=seed))
