from repro.data.pipeline import synthetic_lm_batches, TokenPipeline  # noqa: F401
